//! Conformance tests for the quantized fixed-point arena pipeline: exact
//! u8/u16 rank-code lanes must return probability rows **byte-identical**
//! to the f32 kernel for every tree-based registry model, on both
//! execution backends and through the full `ModelSpec` serving surface —
//! quantization changes the lane width, never an answer or a comparator
//! count. Lossy lanes are bounded by an accuracy-delta check, and the
//! quantizer's edge cases (non-finite features, constant features,
//! out-of-range thresholds, leaf-only forests) walk exactly like f32.
//!
//! The same discipline holds one dispatch level down: the integer lanes
//! run under explicitly vectorized kernels (`exec::simd`) when the host
//! has them, and tests (h)/(i) pin native vector dispatch — and every
//! individually-supported [`SimdLevel`] — byte-identical to the forced
//! scalar loop through the model surface and both backends.

use fog::api::{BackendKind, Classifier, Estimator, ModelSpec, RfModel};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::dt::FlatTree;
use fog::exec::{BatchPlan, ForestArena, QuantMode, Reduce, SimdLevel};
use fog::forest::{ForestParams, RandomForest, VoteMode};

const TREE_MODELS: &[&str] = &["fog_opt", "fog_max", "rf", "rf_prob"];

fn data() -> Dataset {
    generate(&DatasetProfile::demo(), 733)
}

/// A single hand-built tree whose feature-0 cut count forces the u16
/// lane: `2^depth - 1` distinct live thresholds on one feature (255 cuts
/// at depth 8 — one past the u8 fit bound of 254).
fn wide_cut_tree(depth: usize, n_classes: usize) -> FlatTree {
    let n_nodes = (1usize << depth) - 1;
    let n_leaves = 1usize << depth;
    let thr: Vec<f32> = (0..n_nodes).map(|i| i as f32 * 0.37 - 20.0).collect();
    let mut leaf = vec![0.0f32; n_leaves * n_classes];
    for (i, row) in leaf.chunks_exact_mut(n_classes).enumerate() {
        row[i % n_classes] = 1.0;
    }
    FlatTree { depth, n_features: 2, n_classes, feat: vec![0; n_nodes], thr, leaf }
}

/// (a) Exact quantization is answer-identical end to end: for every
/// tree-based registry model, a `--quant`-enabled spec returns rows
/// byte-identical to the plain spec through the direct batch path and
/// both execution backends (FoG specs ignore the knob, so equality there
/// pins the no-op).
#[test]
fn exact_quant_byte_identical_for_all_registry_models() {
    let ds = data();
    let n = ds.test.len();
    for name in TREE_MODELS {
        let make = |quant: QuantMode| {
            ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
                .unwrap_or_else(|| panic!("registry name '{name}' missing"))
                .fast()
                .with_quant(quant)
                .fit(&ds.train, 57)
        };
        let plain = make(QuantMode::Off);
        let quantized = make(QuantMode::Exact);
        let want = plain.predict_proba_batch(&ds.test.x, n);
        let got = quantized.predict_proba_batch(&ds.test.x, n);
        assert_eq!(want, got, "{name}: exact quantization changed the direct path");
        for kind in [BackendKind::Software, BackendKind::Uarch] {
            let be = quantized
                .exec_backend(kind)
                .unwrap_or_else(|| panic!("{name}: no {} backend", kind.label()));
            let (probs, _) = be.evaluate_tile(&ds.test.x, n);
            assert_eq!(
                want, probs,
                "{name}: exact quantization changed a {} backend answer",
                kind.label()
            );
        }
    }
}

/// (b) Accounting is quantization-invariant: comparator ops stay the
/// padded-depth hardware charge and `levels_skipped` the ragged saving,
/// byte-for-byte equal between `--quant off` and exact lanes on both
/// backends (Table 1 / Fig 4–5 inputs unchanged).
#[test]
fn quantization_leaves_comparator_accounting_unchanged() {
    let ds = data();
    let n = ds.test.len();
    let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 21);
    for kind in [BackendKind::Software, BackendKind::Uarch] {
        let plain = RfModel::new(rf.clone(), VoteMode::ProbAverage);
        let quantized =
            RfModel::new(rf.clone(), VoteMode::ProbAverage).with_quant(QuantMode::Exact);
        let (_, r_off) = plain.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
        let (_, r_q) = quantized.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
        assert_eq!(r_off, r_q, "{}: quantization changed accounting", kind.label());
        assert!(r_q.comparator_ops > 0, "fixture evaluated nothing");
    }
}

/// (c) The u16 lane: a forest whose per-feature cut count exceeds the u8
/// bound packs only `thr_q16`, and its exact walk is still byte-identical
/// to f32.
#[test]
fn u16_lane_covers_wide_cut_forests_bitwise() {
    let tree = wide_cut_tree(8, 3);
    let arena = ForestArena::from_flat_trees(&[tree.clone(), tree]);
    assert_eq!(arena.quant_lane(), Some("u16"), "255 cuts must overflow the u8 lane");
    // Rows probing below/above every cut, between cuts, and exactly on
    // cut values (the `>` boundary the rank codes must preserve).
    let mut x = Vec::new();
    for i in 0..300 {
        x.extend_from_slice(&[i as f32 * 0.37 - 20.0, 0.0]);
        x.extend_from_slice(&[i as f32 * 0.37 - 20.185, 1.0]);
    }
    let n = x.len() / 2;
    let want = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&x, n);
    let got = BatchPlan::new(&arena, Reduce::ProbAverage)
        .with_quant(QuantMode::Exact)
        .execute(&x, n);
    assert_eq!(want, got, "u16 lane diverged from the f32 walk");
}

/// (d) Non-finite features walk identically: NaN routes left like the
/// f32 `>` (false on NaN), +inf routes right past every live cut, -inf
/// left — all byte-identical through the quantized path.
#[test]
fn non_finite_features_walk_like_f32() {
    let ds = data();
    let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 5);
    let arena = ForestArena::from_forest(&rf, rf.max_depth());
    let f = ds.n_features();
    let mut x = ds.test.x[..8 * f].to_vec();
    x[0] = f32::NAN;
    x[f + 1] = f32::INFINITY;
    x[2 * f + 2] = f32::NEG_INFINITY;
    x[3 * f] = f32::NAN;
    x[3 * f + 1] = f32::INFINITY;
    for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
        let want = BatchPlan::new(&arena, reduce).execute(&x, 8);
        let got = BatchPlan::new(&arena, reduce).with_quant(QuantMode::Exact).execute(&x, 8);
        assert_eq!(want, got, "{reduce:?}: non-finite features diverged");
    }
}

/// (e) Thresholds outside the observed feature range and constant
/// features: every sample routes left of an unreachable cut (and lossy's
/// zero-range branch stays a valid walk), byte-identical for exact.
#[test]
fn out_of_range_thresholds_and_constant_features() {
    // Feature 0 splits at +100 (unreachable for inputs in [-1, 1]);
    // feature 1 is never split on (cut-free → every value codes to 0).
    let n_nodes = 3;
    let tree = FlatTree {
        depth: 2,
        n_features: 2,
        n_classes: 2,
        feat: vec![0; n_nodes],
        thr: vec![100.0, -100.0, 100.0],
        leaf: vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0],
    };
    let arena = ForestArena::from_flat_trees(&[tree.clone(), tree]);
    let x: Vec<f32> = (0..12).flat_map(|i| [i as f32 * 0.1 - 0.5, 7.0]).collect();
    let n = x.len() / 2;
    let want = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&x, n);
    let got =
        BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(QuantMode::Exact).execute(&x, n);
    assert_eq!(want, got, "out-of-range thresholds diverged");
    let lossy = BatchPlan::new(&arena, Reduce::ProbAverage)
        .with_quant(QuantMode::Lossy { bits: 8 })
        .execute(&x, n);
    for i in 0..n {
        let sum: f32 = lossy.row(i).iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "lossy row {i} not a distribution");
    }
}

/// (f) Depth-0 leaf-only forests: zero live levels, zero cuts — the
/// quantized path runs the same zero-level walk and returns the leaf
/// averages bitwise.
#[test]
fn leaf_only_forest_through_quantized_path() {
    let leaf_tree = FlatTree {
        depth: 0,
        n_features: 2,
        n_classes: 3,
        feat: vec![],
        thr: vec![],
        leaf: vec![0.0, 1.0, 0.0],
    };
    let arena = ForestArena::from_flat_trees(&[leaf_tree.clone(), leaf_tree]);
    let x = [1.0f32, 2.0, f32::NAN, -3.0];
    for quant in [QuantMode::Exact, QuantMode::Lossy { bits: 8 }] {
        let probs =
            BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(quant).execute(&x, 2);
        for i in 0..2 {
            assert_eq!(probs.row(i), &[0.0, 1.0, 0.0], "{quant:?} row {i}");
        }
    }
}

/// (h) Vector dispatch is answer-invariant through the model surface:
/// the natively-dispatched exact lanes — direct batch path and both
/// execution backends, both vote modes — must match a forced-scalar
/// `BatchPlan` on the same arena byte for byte. (Test (a) extends the
/// pin to all four tree registry models: its quantized models dispatch
/// natively, so equality with the plain f32 path pins SIMD transitively;
/// FoG specs ignore the knob and stay on scalar f32 lanes.) Accounting
/// is dispatch-invariant by test (b): the backends there also resolve
/// native dispatch, and their reports equal the `--quant off` run's.
#[test]
fn simd_dispatch_byte_identical_through_model_surface() {
    let ds = data();
    let n = ds.test.len();
    let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 31);
    for (mode, reduce) in [
        (VoteMode::ProbAverage, Reduce::ProbAverage),
        (VoteMode::Majority, Reduce::MajorityVote),
    ] {
        let model = RfModel::new(rf.clone(), mode).with_quant(QuantMode::Exact);
        assert!(
            Classifier::simd_level(&model).supported(),
            "model must resolve a level its host can execute"
        );
        let scalar = BatchPlan::new(model.arena(), reduce)
            .with_quant(QuantMode::Exact)
            .with_simd(SimdLevel::Scalar)
            .execute(&ds.test.x, n);
        let direct = Classifier::predict_proba_batch(&model, &ds.test.x, n);
        assert_eq!(direct, scalar, "{mode:?}: native dispatch changed the direct path");
        for kind in [BackendKind::Software, BackendKind::Uarch] {
            let (probs, _) = model.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
            assert_eq!(
                probs,
                scalar,
                "{mode:?}: native dispatch changed a {} backend answer",
                kind.label()
            );
        }
    }
}

/// (i) The 255-cut u16-lane hand forest under every vector level this
/// host supports: byte-identical to the forced-scalar lane, including
/// rows landing exactly on cut values (the `>` boundary the sign-biased
/// vector compares must preserve).
#[test]
fn u16_wide_cut_forest_simd_matches_scalar_at_every_level() {
    let tree = wide_cut_tree(8, 3);
    let arena = ForestArena::from_flat_trees(&[tree.clone(), tree]);
    assert_eq!(arena.quant_lane(), Some("u16"), "255 cuts must overflow the u8 lane");
    let mut x = Vec::new();
    for i in 0..300 {
        x.extend_from_slice(&[i as f32 * 0.37 - 20.0, 0.0]);
        x.extend_from_slice(&[i as f32 * 0.37 - 20.185, 1.0]);
    }
    let n = x.len() / 2;
    let scalar = BatchPlan::new(&arena, Reduce::ProbAverage)
        .with_quant(QuantMode::Exact)
        .with_simd(SimdLevel::Scalar)
        .execute(&x, n);
    for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::detect()] {
        if !level.supported() {
            continue;
        }
        let got = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .with_simd(level)
            .execute(&x, n);
        assert_eq!(scalar, got, "{} diverged on the u16 wide-cut forest", level.label());
    }
}

/// (g) Lossy lanes are bounded: 8-bit affine codes stay within a small
/// accuracy delta of the f32 model on the demo suite (the knob trades
/// precision for lane width, not correctness).
#[test]
fn lossy_accuracy_delta_is_bounded() {
    let ds = data();
    let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 11);
    let plain = RfModel::new(rf.clone(), VoteMode::ProbAverage);
    let acc_plain = Classifier::accuracy(&plain, &ds.test);
    for bits in [8u8, 16] {
        let lossy = RfModel::new(rf.clone(), VoteMode::ProbAverage)
            .with_quant(QuantMode::Lossy { bits });
        let acc = Classifier::accuracy(&lossy, &ds.test);
        assert!(
            (acc_plain - acc).abs() <= 0.05,
            "lossy{bits} accuracy {acc} drifted from {acc_plain}"
        );
    }
}
