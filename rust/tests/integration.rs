//! Cross-module integration tests: the full train → split → evaluate
//! pipeline, agreement between the three evaluation paths (software
//! Algorithm 2, cycle-level μarch simulation, serving coordinator), and
//! the PJRT artifact path when artifacts are present.

use fog::coordinator::{Backend, FogServer, ServerConfig};
use fog::data::normalize::{quantize_split, standardize};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::dt::TreeParams;
use fog::fog::{FieldOfGroves, FogParams};
use fog::forest::{ForestParams, RandomForest, VoteMode};
use fog::uarch::{RingConfig, RingSim};

fn pipeline() -> (FieldOfGroves, fog::data::Dataset, RandomForest) {
    let mut ds = generate(&DatasetProfile::demo(), 77);
    standardize(&mut ds);
    quantize_split(&mut ds.train);
    quantize_split(&mut ds.test);
    let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 7);
    let fog = FieldOfGroves::from_forest_shuffled(&rf, 4, Some(7));
    (fog, ds, rf)
}

#[test]
fn three_eval_paths_agree() {
    let (fog, ds, _) = pipeline();
    let threshold = 0.3f32;
    let seed = 99u64;

    let sw = fog.evaluate(
        &ds.test.x,
        &FogParams { threshold, max_hops: fog.n_groves(), seed },
    );

    let mut sim = RingSim::new(&fog, RingConfig { threshold, seed, ..Default::default() });
    sim.load_batch(&ds.test.x);
    let sim_out = sim.run().to_vec();

    let mut server = FogServer::start(
        &fog,
        &ServerConfig { threshold, seed, backend: Backend::Native, ..Default::default() },
    )
    .unwrap();
    let served = server.classify(&ds.test.x);
    server.shutdown();

    assert_eq!(sw.outcomes.len(), sim_out.len());
    assert_eq!(sw.outcomes.len(), served.len());
    for i in 0..sw.outcomes.len() {
        assert_eq!(sw.outcomes[i].label, sim_out[i].label, "sim label {i}");
        assert_eq!(sw.outcomes[i].hops, sim_out[i].hops, "sim hops {i}");
        assert_eq!(sw.outcomes[i].label, served[i].label, "served label {i}");
        assert_eq!(sw.outcomes[i].hops, served[i].hops, "served hops {i}");
    }
}

#[test]
fn fog_max_equals_rf_prob_average_accuracy() {
    let (fog, ds, rf) = pipeline();
    let res = fog.evaluate(&ds.test.x, &FogParams::fog_max(fog.n_groves()));
    let fog_acc = res.accuracy(&ds.test.y);
    let rf_acc = rf.accuracy(&ds.test, VoteMode::ProbAverage);
    assert!((fog_acc - rf_acc).abs() < 1e-9, "fog_max {fog_acc} vs rf {rf_acc}");
}

#[test]
fn quantization_cost_is_small() {
    // The Q3.4 hardware quantization must not destroy accuracy.
    let mut raw = generate(&DatasetProfile::demo(), 78);
    standardize(&mut raw);
    let rf_raw = RandomForest::fit(&raw.train, &ForestParams::default(), 3);
    let acc_raw = rf_raw.accuracy(&raw.test, VoteMode::Majority);

    let mut quant = raw.clone();
    quantize_split(&mut quant.train);
    quantize_split(&mut quant.test);
    let rf_q = RandomForest::fit(&quant.train, &ForestParams::default(), 3);
    let acc_q = rf_q.accuracy(&quant.test, VoteMode::Majority);
    assert!(acc_raw - acc_q < 0.06, "quantization cost {acc_raw} -> {acc_q}");
}

#[test]
fn deeper_forest_does_not_collapse() {
    let mut ds = generate(&DatasetProfile::demo(), 79);
    standardize(&mut ds);
    let params = ForestParams {
        n_trees: 16,
        tree: TreeParams { max_depth: 12, ..Default::default() },
        bootstrap: true,
    };
    let rf = RandomForest::fit(&ds.train, &params, 4);
    assert!(rf.accuracy(&ds.test, VoteMode::Majority) > 0.6);
    let fog = FieldOfGroves::from_forest(&rf, 4);
    let res = fog.evaluate(&ds.test.x, &FogParams { threshold: 0.3, max_hops: 4, seed: 4 });
    assert!(res.accuracy(&ds.test.y) > 0.55);
}

#[test]
fn pjrt_serving_agrees_with_native_when_artifacts_exist() {
    let dir = fog::runtime::artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT serving test: run `make artifacts`");
        return;
    }
    // Shape the fog to the demo artifact: t=4, depth 6, f=8, c=3.
    let mut ds = generate(&DatasetProfile::demo(), 80);
    standardize(&mut ds);
    quantize_split(&mut ds.train);
    quantize_split(&mut ds.test);
    let params = ForestParams {
        n_trees: 8,
        tree: TreeParams { max_depth: 6, ..Default::default() },
        bootstrap: true,
    };
    let rf = RandomForest::fit(&ds.train, &params, 5);
    let fog = FieldOfGroves::from_forest_shuffled(&rf, 4, Some(5));
    if fog.depth > 6 {
        eprintln!("skipping: trained deeper than artifact");
        return;
    }
    let fog = fog.repad(6);

    let run = |backend: Backend| {
        let mut server = FogServer::start(
            &fog,
            &ServerConfig { threshold: 0.3, seed: 11, backend, ..Default::default() },
        )
        .unwrap();
        let out = server.classify(&ds.test.x);
        server.shutdown();
        out
    };
    let native = run(Backend::Native);
    let pjrt = run(Backend::Pjrt { artifacts_dir: dir });
    assert_eq!(native.len(), pjrt.len());
    let mut label_mismatch = 0;
    for (a, b) in native.iter().zip(&pjrt) {
        if a.label != b.label {
            label_mismatch += 1;
        }
        // hops can differ only at f32 confidence boundaries; labels must
        // agree except at exact probability ties.
    }
    assert!(
        label_mismatch <= native.len() / 50,
        "labels diverged on {label_mismatch}/{} inputs",
        native.len()
    );
}

#[test]
fn budgeted_training_pipeline() {
    let mut ds = generate(&DatasetProfile::demo(), 81);
    standardize(&mut ds);
    // Feature costs: make the second half of features expensive.
    let costs: Vec<f32> = (0..ds.train.n_features)
        .map(|f| if f >= ds.train.n_features / 2 { 8.0 } else { 1.0 })
        .collect();
    let loose =
        fog::forest::budgeted::fit_budgeted(&ds.train, &ForestParams::small(), &costs, f64::INFINITY, 6);
    let budget = loose.chosen.avg_cost * 0.6;
    let tight =
        fog::forest::budgeted::fit_budgeted(&ds.train, &ForestParams::small(), &costs, budget, 6);
    assert!(tight.chosen.avg_cost <= loose.chosen.avg_cost + 1e-9);
    // The tight forest still classifies (graceful degradation).
    let acc = tight.forest.accuracy(&ds.test, VoteMode::Majority);
    assert!(acc > 0.5, "budgeted acc {acc}");
}
