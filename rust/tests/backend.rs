//! Conformance tests for the pluggable execution backends: for every
//! tree-based registry model (`fog_opt`, `fog_max`, `rf`, `rf_prob`) the
//! hardware-in-the-loop `UarchBackend` must return probability rows
//! **byte-identical** to the `SoftwareBackend` (the simulator changes
//! *accounting*, never *answers*), and its per-classification
//! comparator-op counts must equal the existing arena-derived μarch
//! accounting — so Table 1 / Fig 4–5 numbers are unchanged by the
//! backend split.

use fog::api::{BackendKind, Classifier, Estimator, FogModel, ModelSpec, RfModel};
use fog::coordinator::{ModelServerConfig, ShardedServer, ShardedServerConfig};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::energy::model::ClassifierKind;
use fog::exec::ExecReport;
use fog::forest::{ForestParams, RandomForest, VoteMode};
use fog::{FieldOfGroves, FogParams};
use std::sync::Arc;

const TREE_MODELS: &[&str] = &["fog_opt", "fog_max", "rf", "rf_prob"];

fn data() -> Dataset {
    generate(&DatasetProfile::demo(), 711)
}

/// (a) Byte-identical probabilities across backends for every tree-based
/// registry model, whole-split and odd-sized tiles alike — and both
/// backends byte-identical to the model's direct batch path.
#[test]
fn uarch_probabilities_byte_identical_to_software() {
    let ds = data();
    let n = ds.test.len();
    let f = ds.n_features();
    for name in TREE_MODELS {
        let model = ModelSpec::for_shape(name, f, ds.n_classes())
            .unwrap_or_else(|| panic!("registry name '{name}' missing"))
            .fast()
            .fit(&ds.train, 33);
        let sw = model
            .exec_backend(BackendKind::Software)
            .unwrap_or_else(|| panic!("{name}: no software backend"));
        let ua = model
            .exec_backend(BackendKind::Uarch)
            .unwrap_or_else(|| panic!("{name}: no uarch backend"));
        let direct = model.predict_proba_batch(&ds.test.x, n);

        let (p_sw, _) = sw.evaluate_tile(&ds.test.x, n);
        let (p_ua, _) = ua.evaluate_tile(&ds.test.x, n);
        assert_eq!(p_sw, direct, "{name}: software backend diverged from direct path");
        assert_eq!(p_sw, p_ua, "{name}: uarch backend changed an answer");

        // Tile-composition independence: an odd split point must not
        // change a single byte.
        let cut = 7.min(n);
        let (head, _) = ua.evaluate_tile(&ds.test.x[..cut * f], cut);
        for i in 0..cut {
            assert_eq!(head.row(i), direct.row(i), "{name}: tile split changed row {i}");
        }
    }
}

/// (b) Uarch comparator-op counts equal the arena-derived accounting —
/// forests charge trees × padded depth per sample; FoG charges every
/// visited grove's `ops_per_eval`, replayed independently via
/// Algorithm 2.
#[test]
fn uarch_comparator_ops_equal_arena_accounting() {
    let ds = data();
    let n = ds.test.len();

    // Forest: closed form from the arena layout.
    let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 17);
    for mode in [VoteMode::Majority, VoteMode::ProbAverage] {
        let model = RfModel::new(rf.clone(), mode);
        let expected = (n * model.arena().ops_per_eval_range(0, model.arena().n_trees())) as u64;
        let ua = model.exec_backend(BackendKind::Uarch).unwrap();
        let (_, report) = ua.evaluate_tile(&ds.test.x, n);
        assert_eq!(report.comparator_ops, expected, "rf {mode:?} op count drifted");
        assert_eq!(report.samples, n as u64);
        assert_eq!(report.hops_total, n as u64);
    }

    // FoG: replay Algorithm 2 per row and sum the visited groves' ops.
    let field = FieldOfGroves::from_forest(&rf, 2);
    let model = FogModel::new(
        field,
        FogParams { threshold: 0.4, max_hops: 4, seed: 21 },
        ClassifierKind::FogOpt,
    );
    let n_groves = model.fog.n_groves();
    let mut expected = 0u64;
    let mut expected_hops = 0u64;
    for i in 0..n {
        let row = ds.test.row(i);
        let outcome = model.eval_row(row);
        let start = model.start_grove(row);
        for j in 0..outcome.hops {
            expected += model.fog.groves[(start + j) % n_groves].ops_per_eval() as u64;
        }
        expected_hops += outcome.hops as u64;
    }
    for kind in [BackendKind::Software, BackendKind::Uarch] {
        let backend = model.exec_backend(kind).unwrap();
        let (_, report) = backend.evaluate_tile(&ds.test.x, n);
        assert_eq!(
            report.comparator_ops, expected,
            "fog {} op count != arena-derived accounting",
            backend.name()
        );
        assert_eq!(report.hops_total, expected_hops, "fog {} hop total", backend.name());
    }
}

/// (b') Ragged forests through both backends: a mixed-depth arena keeps
/// software and μarch answers byte-identical and the comparator-op
/// charge at the pre-exit padded number, while the software report now
/// carries the levels the live-depth early exit skipped (the μarch PE is
/// depth-bound, so it reports zero skipped levels).
#[test]
fn ragged_arena_backends_agree_and_account_skips() {
    use fog::dt::{FlatTree, TreeParams};
    use fog::exec::ForestArena;
    let ds = data();
    let deep = RandomForest::fit(&ds.train, &ForestParams::small(), 27);
    let shallow_params = fog::forest::ForestParams {
        n_trees: 4,
        tree: TreeParams { max_depth: 2, ..TreeParams::default() },
        bootstrap: true,
    };
    let shallow = RandomForest::fit(&ds.train, &shallow_params, 28);
    let mut trees: Vec<FlatTree> = deep.flatten(deep.max_depth());
    trees.extend(shallow.flatten(shallow.max_depth()));
    let arena = ForestArena::from_flat_trees(&trees);
    let skipped_per_eval = arena.skipped_ops_per_eval_range(0, arena.n_trees());
    assert!(skipped_per_eval > 0, "fixture must actually be ragged");

    let n = ds.test.len();
    use fog::exec::{Backend, Reduce, SoftwareBackend, UarchBackend};
    let arena = Arc::new(arena);
    let sw = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage);
    let ua = UarchBackend::forest(Arc::clone(&arena), Reduce::ProbAverage);
    let (p_sw, r_sw) = sw.evaluate_tile(&ds.test.x, n);
    let (p_ua, r_ua) = ua.evaluate_tile(&ds.test.x, n);
    assert_eq!(p_sw, p_ua, "ragged arena: uarch backend changed an answer");
    // Charge stays the padded pre-exit number on both backends.
    let expected_ops = (n * arena.ops_per_eval_range(0, arena.n_trees())) as u64;
    assert_eq!(r_sw.comparator_ops, expected_ops);
    assert_eq!(r_ua.comparator_ops, expected_ops);
    // The software kernel reports its skip; depth-bound hardware doesn't.
    assert_eq!(r_sw.levels_skipped, (n * skipped_per_eval) as u64);
    assert_eq!(r_ua.levels_skipped, 0);
    assert!(r_sw.levels_skipped_per_class() > 0.0);
}

/// (c) Only the uarch backend reports cycles and energy; the software
/// backend reports the same op counts with zero hardware accounting.
#[test]
fn accounting_split_between_backends() {
    let ds = data();
    let n = ds.test.len();
    let model = ModelSpec::for_shape("rf", ds.n_features(), ds.n_classes())
        .unwrap()
        .fast()
        .fit(&ds.train, 5);
    let (_, sw) = model
        .exec_backend(BackendKind::Software)
        .unwrap()
        .evaluate_tile(&ds.test.x, n);
    let (_, ua) = model
        .exec_backend(BackendKind::Uarch)
        .unwrap()
        .evaluate_tile(&ds.test.x, n);
    assert_eq!(sw.comparator_ops, ua.comparator_ops);
    assert_eq!(sw.cycles, 0);
    assert_eq!(sw.energy_nj, 0.0);
    assert!(ua.cycles > 0, "uarch reported no cycles");
    assert!(
        ua.energy_per_class_nj() > 0.0 && ua.energy_per_class_nj().is_finite(),
        "uarch energy/class must be finite nonzero, got {}",
        ua.energy_per_class_nj()
    );
}

/// (d) Dense baselines have no arena engine: `exec_backend` is `None`
/// for every kind, and serving falls back to the model's batch path.
#[test]
fn dense_baselines_have_no_exec_backend() {
    let ds = data();
    for name in ["svm_lr", "mlp"] {
        let model = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap()
            .fast()
            .fit(&ds.train, 3);
        assert!(model.exec_backend(BackendKind::Software).is_none(), "{name}");
        assert!(model.exec_backend(BackendKind::Uarch).is_none(), "{name}");
    }
}

/// (e) End to end through the sharded tier: a uarch fleet answers
/// byte-identically to a software fleet and its merged snapshot carries
/// finite nonzero per-classification energy and cycles — the
/// `fog serve --backend uarch` contract.
#[test]
fn sharded_uarch_serving_reports_live_energy() {
    let ds = data();
    let spec = ModelSpec::for_shape("fog_opt", ds.n_features(), ds.n_classes())
        .unwrap()
        .fast();
    let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 44));

    let serve = |backend: BackendKind| {
        let cfg = ShardedServerConfig {
            replicas: 2,
            worker: ModelServerConfig { backend, ..Default::default() },
            ..Default::default()
        };
        let mut server = ShardedServer::start(Arc::clone(&model), &cfg);
        let responses = server.classify(&ds.test.x).expect("aligned batch");
        let snap = server.snapshot();
        let replica_snaps: Vec<_> =
            (0..server.n_replicas()).map(|r| server.replica_metrics(r).snapshot()).collect();
        server.shutdown();
        (responses, snap, replica_snaps)
    };

    let (sw, _, _) = serve(BackendKind::Software);
    let (ua, snap, replicas) = serve(BackendKind::Uarch);
    for (a, b) in sw.iter().zip(&ua) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.prob, b.prob, "served uarch row is not byte-identical");
    }
    assert_eq!(snap.exec_samples as usize, ds.test.len());
    let e = snap.energy_per_class_nj();
    assert!(e > 0.0 && e.is_finite(), "aggregate energy/class: {e}");
    assert!(snap.cycles_per_class() > 0.0);
    // Per-replica reports merge into the aggregate (saturating adds).
    let mut merged = ExecReport::default();
    for rs in &replicas {
        merged.samples += rs.exec_samples;
        merged.cycles += rs.exec_cycles;
    }
    assert_eq!(merged.samples, snap.exec_samples);
    assert_eq!(merged.cycles, snap.exec_cycles);
}
