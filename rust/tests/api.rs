//! Conformance tests for the unified `fog::api` layer: every registry
//! entry must train on a small synthetic dataset, agree between batch and
//! per-sample prediction, and be deterministic under a fixed seed.

use fog::api::{Classifier, Estimator, ModelSpec, REGISTRY};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};

fn small_data() -> Dataset {
    generate(&DatasetProfile::demo(), 401)
}

fn fit_fast(name: &str, ds: &Dataset, seed: u64) -> Box<dyn Classifier> {
    ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
        .unwrap_or_else(|| panic!("registry name '{name}' missing"))
        .fast()
        .fit(&ds.train, seed)
}

#[test]
fn every_registry_entry_trains_and_reports_shape() {
    let ds = small_data();
    for name in REGISTRY {
        let model = fit_fast(name, &ds, 11);
        assert_eq!(model.n_features(), ds.n_features(), "{name}");
        assert_eq!(model.n_classes(), ds.n_classes(), "{name}");
        assert!(!model.name().is_empty(), "{name}");
        // Clearly not broken on the easy demo profile (the `fast()`
        // budgets undertrain, so the bar is "better than ~chance", not
        // "paper accuracy").
        let acc = model.accuracy(&ds.test);
        assert!(acc > 1.0 / ds.n_classes() as f64 - 0.05, "{name}: accuracy {acc}");
    }
}

#[test]
fn batch_and_per_sample_predictions_agree() {
    let ds = small_data();
    for name in REGISTRY {
        let model = fit_fast(name, &ds, 12);
        let n = ds.test.len();
        let batch_labels = model.predict_batch(&ds.test.x, n);
        let batch_probs = model.predict_proba_batch(&ds.test.x, n);
        assert_eq!(batch_probs.n_rows(), n, "{name}");
        for i in (0..n).step_by(5) {
            let row = ds.test.row(i);
            assert_eq!(batch_labels[i], model.predict(row), "{name} row {i}: label");
            let single = model.predict_proba(row);
            for (a, b) in batch_probs.row(i).iter().zip(&single) {
                assert!((a - b).abs() < 1e-6, "{name} row {i}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn probability_rows_are_distributions() {
    let ds = small_data();
    for name in REGISTRY {
        let model = fit_fast(name, &ds, 13);
        let probs = model.predict_proba_batch(&ds.test.x, ds.test.len());
        for i in (0..probs.n_rows()).step_by(11) {
            let row = probs.row(i);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "{name} row {i} sums to {sum}");
            assert!(row.iter().all(|&p| (-1e-6..=1.0 + 1e-6).contains(&p)), "{name} row {i}");
        }
    }
}

#[test]
fn deterministic_under_fixed_seed() {
    let ds = small_data();
    for name in REGISTRY {
        let a = fit_fast(name, &ds, 14);
        let b = fit_fast(name, &ds, 14);
        assert_eq!(
            a.predict_batch(&ds.test.x, ds.test.len()),
            b.predict_batch(&ds.test.x, ds.test.len()),
            "{name}: refit with the same seed changed predictions"
        );
    }
}

#[test]
fn cost_reports_are_positive_and_probe_sensitive() {
    let ds = small_data();
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    for name in REGISTRY {
        let model = fit_fast(name, &ds, 15);
        let measured = model.cost_report(Some(&ds.test), &eb, &ab);
        let bound = model.cost_report(None, &eb, &ab);
        for r in [&measured, &bound] {
            assert!(r.energy_nj > 0.0, "{name}");
            assert!(r.latency_ns > 0.0, "{name}");
            assert!(r.area_mm2 > 0.0, "{name}");
        }
        // The probe-free bound must never undercharge relative to the
        // measured point (worst-case depth / full circulation).
        assert!(
            bound.energy_nj + 1e-9 >= measured.energy_nj,
            "{name}: bound {} < measured {}",
            bound.energy_nj,
            measured.energy_nj
        );
        assert_eq!(measured.kind, model.kind(), "{name}");
    }
}

#[test]
fn fog_opt_costs_less_than_fog_max_on_probe() {
    let ds = small_data();
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let opt = fit_fast("fog_opt", &ds, 16);
    let max = fit_fast("fog_max", &ds, 16);
    let e_opt = opt.cost_report(Some(&ds.test), &eb, &ab).energy_nj;
    let e_max = max.cost_report(Some(&ds.test), &eb, &ab).energy_nj;
    assert!(
        e_opt <= e_max + 1e-9,
        "confidence gating should not cost more than full circulation: {e_opt} vs {e_max}"
    );
}
