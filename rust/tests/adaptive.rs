//! Conformance + property tests for the adaptive confidence early-exit
//! serving path (`--adaptive-conf`, Daghero et al., arXiv 2205.13838):
//!
//! * **Full-threshold pin** — `t = 1.0` is byte-identical to running
//!   without the flag for every tree-based registry model, on both
//!   execution backends, with quantization off *and* on, including the
//!   whole accounting report.
//! * **Accounting split** — at every threshold `comparator_ops` stays
//!   the paper-faithful padded-depth charge (Table 1 / Fig 4–5 inputs
//!   unchanged); the saving surfaces only in the separate
//!   `trees_skipped` gauge, on which both backends agree.
//! * **Batch composition** — answers for a sample depend only on that
//!   sample, never on how the batch around it was packed.
//! * **Cache tagging** — a sharded server caches early-exit rows under
//!   a threshold tag, so differently-thresholded servers can never
//!   replay each other's rows; `t = 1.0` shares the full-evaluation
//!   key space, and capacity-0 / no-cache configs still serve.
//! * **Fleet replay** — seeded open-loop outcome counters are
//!   bit-identical across worker counts with adaptive mode on, and the
//!   skip gauge surfaces in the merged fleet metrics.

use fog::api::{BackendKind, Classifier, Estimator, ModelSpec};
use fog::coordinator::{
    loadgen, CacheConfig, Fleet, FleetConfig, FleetRequest, LoadgenConfig, LoadgenReport,
    RouterPolicy, ShardedServer, ShardedServerConfig,
};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::exec::QuantMode;
use std::sync::Arc;

const TREE_MODELS: &[&str] = &["fog_opt", "fog_max", "rf", "rf_prob"];

fn data() -> Dataset {
    generate(&DatasetProfile::demo(), 733)
}

fn fit(name: &str, ds: &Dataset, quant: QuantMode, adaptive: Option<f32>) -> Box<dyn Classifier> {
    let mut spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
        .unwrap_or_else(|| panic!("registry name '{name}' missing"))
        .fast()
        .with_quant(quant);
    if let Some(t) = adaptive {
        spec = spec.with_adaptive(t);
    }
    spec.fit(&ds.train, 57)
}

/// (a) The conformance matrix: `t = 1.0` must be indistinguishable from
/// full evaluation for every tree-based registry model × both execution
/// backends × quantization off|on — byte-identical probability rows
/// through the direct batch path and `evaluate_tile`, and an *equal
/// whole report* (zero `trees_skipped`, untouched comparator charge).
#[test]
fn full_threshold_is_byte_identical_for_all_registry_models() {
    let ds = data();
    let n = ds.test.len();
    for name in TREE_MODELS {
        for quant in [QuantMode::Off, QuantMode::Exact] {
            let plain = fit(name, &ds, quant, None);
            let pinned = fit(name, &ds, quant, Some(1.0));
            assert!(
                pinned.adaptive_conf().is_none(),
                "{name}: t = 1.0 must filter to full evaluation"
            );
            let want = plain.predict_proba_batch(&ds.test.x, n);
            let got = pinned.predict_proba_batch(&ds.test.x, n);
            assert_eq!(want, got, "{name}/{quant:?}: t = 1.0 changed the direct path");
            for kind in [BackendKind::Software, BackendKind::Uarch] {
                let (p0, r0) = plain.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
                let (p1, r1) = pinned.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
                assert_eq!(
                    p0,
                    p1,
                    "{name}/{quant:?}: t = 1.0 changed a {} backend answer",
                    kind.label()
                );
                assert_eq!(
                    r0,
                    r1,
                    "{name}/{quant:?}: t = 1.0 changed {} accounting",
                    kind.label()
                );
                assert_eq!(r1.trees_skipped, 0, "{name}: full evaluation skipped a tree");
            }
        }
    }
}

/// (b) A real threshold saves whole trees without moving the hardware
/// charge: at `t = 0.6` the forest path reports nonzero `trees_skipped`
/// on which both backends agree byte-for-byte (rows too), every other
/// counter matches the full-evaluation report, and test accuracy stays
/// within the acceptance delta.
#[test]
fn early_exit_saves_trees_and_keeps_the_comparator_charge() {
    let ds = data();
    let n = ds.test.len();
    let plain = fit("rf_prob", &ds, QuantMode::Off, None);
    let adaptive = fit("rf_prob", &ds, QuantMode::Off, Some(0.6));
    assert_eq!(adaptive.adaptive_conf(), Some(0.6));

    let acc_plain = plain.accuracy(&ds.test);
    let acc_adaptive = adaptive.accuracy(&ds.test);
    assert!(
        (acc_plain - acc_adaptive).abs() <= 0.02,
        "t = 0.6 accuracy {acc_adaptive:.4} drifted more than 0.02 from {acc_plain:.4}"
    );

    let (sw_probs, sw) =
        adaptive.exec_backend(BackendKind::Software).unwrap().evaluate_tile(&ds.test.x, n);
    let (ua_probs, ua) =
        adaptive.exec_backend(BackendKind::Uarch).unwrap().evaluate_tile(&ds.test.x, n);
    assert!(sw.trees_skipped > 0, "t = 0.6 on the demo suite must skip trees");
    assert_eq!(sw_probs, ua_probs, "backends disagree on adaptive rows");
    assert_eq!(sw.trees_skipped, ua.trees_skipped, "backends disagree on the skip gauge");

    // Zeroing the gauge must recover the full-evaluation report exactly:
    // the saving is reported *beside* the padded-depth charge, never
    // subtracted from it.
    for (kind, report) in [(BackendKind::Software, sw), (BackendKind::Uarch, ua)] {
        let (_, full) = plain.exec_backend(kind).unwrap().evaluate_tile(&ds.test.x, n);
        let mut scrubbed = report;
        scrubbed.trees_skipped = 0;
        assert_eq!(
            scrubbed,
            full,
            "{}: adaptive mode moved a counter other than trees_skipped",
            kind.label()
        );
    }
}

/// (c) Batch-composition independence: a sample's early exit consults
/// only its own running margin, so slicing the same rows into batches of
/// 1, 7, or all-at-once returns byte-identical probability rows.
#[test]
fn answers_are_independent_of_batch_composition() {
    let ds = data();
    let f = ds.n_features();
    let n = ds.test.len();
    let model = fit("rf_prob", &ds, QuantMode::Off, Some(0.5));
    let want = model.predict_proba_batch(&ds.test.x, n);
    for chunk in [1usize, 7] {
        let mut row = 0;
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            let got = model.predict_proba_batch(&ds.test.x[start * f..end * f], end - start);
            for i in 0..end - start {
                assert_eq!(
                    want.row(row + i),
                    got.row(i),
                    "chunk {chunk}: row {} depends on its batch neighbours",
                    row + i
                );
            }
            row += end - start;
            start = end;
        }
    }
}

/// (d) Cache rows are tagged by threshold: a sharded server built from
/// an adaptive model keys its cache under the threshold's bit pattern
/// (so differently-thresholded servers partition the key space), `t =
/// 1.0` keeps the full-evaluation tag 0, warm passes replay
/// byte-identically, and capacity-0 / no-cache configs serve cold.
#[test]
fn sharded_cache_rows_are_tagged_by_threshold() {
    let ds = data();
    let cfg = |cache: Option<CacheConfig>| ShardedServerConfig {
        replicas: 2,
        router: RouterPolicy::RoundRobin,
        router_seed: 0,
        cache,
        ..Default::default()
    };
    let cache = Some(CacheConfig { quant_step: 0.0, ..Default::default() });

    // t = 0.6: the cache carries the threshold tag and warm hits replay
    // the cold rows byte-identically.
    let model: Arc<dyn Classifier> = Arc::from(fit("rf_prob", &ds, QuantMode::Off, Some(0.6)));
    let mut server = ShardedServer::start(Arc::clone(&model), &cfg(cache));
    assert_eq!(
        server.cache().expect("cache configured").tag(),
        0.6f32.to_bits() as u64,
        "adaptive server must tag cached rows with its threshold"
    );
    let cold = server.classify(&ds.test.x).expect("aligned batch");
    let warm = server.classify(&ds.test.x).expect("aligned batch");
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.prob, w.prob, "warm cache replay diverged for id {}", c.id);
    }
    let snap = server.snapshot();
    assert!(snap.cache_hits > 0, "warm pass must hit the cache");
    assert!(snap.exec_trees_skipped > 0, "skip gauge must flow into serving metrics");
    server.shutdown();

    // t = 1.0 filters to full evaluation → tag 0, sharing the plain key
    // space (safe: the rows are byte-identical by test (a)).
    let pinned: Arc<dyn Classifier> = Arc::from(fit("rf_prob", &ds, QuantMode::Off, Some(1.0)));
    let mut server = ShardedServer::start(pinned, &cfg(cache));
    assert_eq!(server.cache().expect("cache configured").tag(), 0);
    server.shutdown();

    // Capacity 0 and `--no-cache` both serve every request cold.
    for cache in [Some(CacheConfig { capacity: 0, ..Default::default() }), None] {
        let mut server = ShardedServer::start(Arc::clone(&model), &cfg(cache));
        assert!(server.cache().is_none(), "capacity 0 must disable the cache");
        let r1 = server.classify(&ds.test.x).expect("aligned batch");
        let r2 = server.classify(&ds.test.x).expect("aligned batch");
        for (a, b) in r1.iter().zip(&r2) {
            assert_eq!(a.prob, b.prob, "cold passes must still be deterministic");
        }
        let snap = server.snapshot();
        assert_eq!(snap.cache_hits, 0, "a disabled cache can never hit");
        server.shutdown();
    }
}

/// Every outcome counter of a loadgen report, fleet-wide then per
/// model; the deterministic fingerprint a seed replay must reproduce.
fn outcome_counts(r: &LoadgenReport) -> Vec<u64> {
    let mut v = vec![r.offered, r.served, r.downgraded, r.shed, r.ticks];
    for m in &r.per_model {
        v.extend([m.requested, m.served, m.downgraded_away, m.downgraded_into, m.shed]);
    }
    v
}

/// (e) Seed-replay regression: with adaptive mode on, the seeded
/// open-loop schedule produces bit-identical outcome counters whether
/// the fleet runs 1 worker or 4 — the early exit is a per-sample
/// property, invisible to admission — and the merged fleet metrics
/// surface a nonzero skip gauge.
#[test]
fn fleet_loadgen_replays_identically_across_worker_counts() {
    let ds = data();
    let lg = LoadgenConfig {
        qps_start: 300.0,
        qps_end: 700.0,
        duration_s: 0.4,
        seed: 7,
        tick_us: 20_000,
        pace: false,
    };
    let run = |replicas: usize| {
        let models: Vec<(String, Arc<dyn Classifier>)> = vec![
            ("rf".to_string(), Arc::from(fit("rf", &ds, QuantMode::Off, Some(0.6)))),
            ("rf_prob".to_string(), Arc::from(fit("rf_prob", &ds, QuantMode::Off, Some(0.6)))),
        ];
        let cfg = FleetConfig { total_replicas: replicas, ..Default::default() };
        let mut fleet = Fleet::start(models, &cfg).expect("fleet start");
        let report = loadgen::run(&mut fleet, &ds.test.x, &lg).expect("loadgen run");
        let snap = fleet.snapshot();
        fleet.shutdown();
        (report, snap)
    };
    let (r1, s1) = run(1);
    let (r4, s4) = run(4);
    assert!(r1.offered > 0 && r1.served > 0, "the schedule must offer traffic");
    assert_eq!(
        outcome_counts(&r1),
        outcome_counts(&r4),
        "adaptive mode made loadgen outcomes depend on the worker count"
    );
    for snap in [&s1, &s4] {
        assert!(
            snap.total.exec_trees_skipped > 0,
            "adaptive fleet must surface the skip gauge in merged metrics"
        );
    }
    assert_eq!(
        s1.total.exec_trees_skipped, s4.total.exec_trees_skipped,
        "the skip gauge must replay with the schedule, independent of workers"
    );
}

/// (f) Requests round-trip through the fleet with adaptive on exactly
/// like the sharded reference: byte-identical rows (the fleet wraps the
/// same server, and the early exit is deterministic).
#[test]
fn adaptive_fleet_matches_sharded_reference_rows() {
    let ds = data();
    let model: Arc<dyn Classifier> = Arc::from(fit("rf_prob", &ds, QuantMode::Off, Some(0.6)));
    let shard_cfg = ShardedServerConfig {
        replicas: 2,
        router: RouterPolicy::RoundRobin,
        router_seed: 0,
        cache: None,
        ..Default::default()
    };
    let mut reference = ShardedServer::start(Arc::clone(&model), &shard_cfg);
    let want = reference.classify(&ds.test.x).expect("aligned batch");
    reference.shutdown();

    let cfg = FleetConfig {
        total_replicas: 2,
        router: RouterPolicy::RoundRobin,
        router_seed: 0,
        cache: None,
        ..Default::default()
    };
    let mut fleet =
        Fleet::start(vec![("rf_prob".to_string(), model)], &cfg).expect("fleet start");
    let reqs = FleetRequest::batch(0, &ds.test.x, ds.n_features()).expect("aligned");
    let got = fleet.classify(&reqs).expect("classify");
    for (r, f) in want.iter().zip(&got) {
        let resp = f.response.as_ref().expect("unlimited budget serves everything");
        assert_eq!(r.prob, resp.prob, "fleet adaptive row diverged for id {}", r.id);
    }
    fleet.shutdown();
}
