//! Acceptance tests for the fleet tier (`coordinator::fleet` +
//! `coordinator::loadgen`):
//!
//! * **Conformance pin** — a single-model fleet with an unlimited budget
//!   is observationally identical to serving its `ShardedServer`
//!   directly: byte-identical probability rows and equal deterministic
//!   metric totals, cold and warm (cache) passes alike.
//! * **Admission outcomes** — budget `0.0` sheds everything even under
//!   `Downgrade`; a budget pinned between the two operating points'
//!   measured energies downgrades `fog_max` traffic onto `fog_opt` with
//!   *exact* outcome counts, in registration order.
//! * **Keyed energy** — per-model snapshot aggregates keep each arena's
//!   nJ/class its own instead of blending heterogeneous models.
//! * **Loadgen determinism** — replaying the same seeded open-loop
//!   schedule reproduces the same `Served`/`Downgraded`/`Shed` counts,
//!   with nonzero shed (strict) and downgrade (fallback) activity under
//!   a tight budget.

use fog::api::{BackendKind, Classifier, Estimator, FleetPolicyKind, ModelSpec, RouterPolicy};
use fog::coordinator::{
    loadgen, CacheConfig, EnergyBudget, Fleet, FleetConfig, FleetOutcome, FleetRequest,
    LoadgenConfig, LoadgenReport, MetricsSnapshot, ModelServerConfig, ShardedServer,
    ShardedServerConfig,
};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::exec::Backend;
use std::sync::Arc;

fn small_data() -> Dataset {
    generate(&DatasetProfile::demo(), 601)
}

fn fit_fast(name: &str, ds: &Dataset, seed: u64) -> Arc<dyn Classifier> {
    Arc::from(
        ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap_or_else(|| panic!("registry name '{name}' missing"))
            .fast()
            .fit(&ds.train, seed),
    )
}

/// A FoG pair with clearly separated uarch energy: `fog_opt` pinned to
/// an aggressive early-exit threshold, `fog_max` visiting every grove.
fn fog_pair(ds: &Dataset) -> (Arc<dyn Classifier>, Arc<dyn Classifier>) {
    let opt: Arc<dyn Classifier> = Arc::from(
        ModelSpec::for_shape("fog_opt", ds.n_features(), ds.n_classes())
            .expect("fog_opt in registry")
            .fast()
            .with_threshold(0.2)
            .fit(&ds.train, 31),
    );
    let max: Arc<dyn Classifier> = Arc::from(
        ModelSpec::for_shape("fog_max", ds.n_features(), ds.n_classes())
            .expect("fog_max in registry")
            .fast()
            .fit(&ds.train, 31),
    );
    (opt, max)
}

/// Standalone uarch energy per classification over the test split.
fn tile_energy_nj(model: &Arc<dyn Classifier>, ds: &Dataset) -> f64 {
    let backend = model.exec_backend(BackendKind::Uarch).expect("uarch backend");
    let (_, report) = backend.evaluate_tile(&ds.test.x, ds.test.len());
    report.energy_per_class_nj()
}

/// The metric totals that are deterministic under the software backend
/// (everything except `batches`, whose grouping is timing-dependent,
/// and the fleet-only `fleet_*` outcome counters).
fn deterministic_counters(s: &MetricsSnapshot) -> [u64; 12] {
    [
        s.requests,
        s.responses,
        s.evals,
        s.hops_total,
        s.forwards,
        s.cache_hits,
        s.cache_misses,
        s.exec_samples,
        s.exec_comparator_ops,
        s.exec_levels_skipped,
        s.exec_cycles,
        s.exec_energy_fj,
    ]
}

/// (ISSUE 6 acceptance) A fleet registering one model under an
/// unlimited budget must be byte-identical to the plain `ShardedServer`
/// it wraps: same probability rows, same ids, same deterministic metric
/// totals — cold pass and cache-warm pass alike.
#[test]
fn single_model_unlimited_fleet_matches_sharded_server() {
    let ds = small_data();
    for name in ["rf", "fog_opt"] {
        let model = fit_fast(name, &ds, 41);
        let cache = Some(CacheConfig { quant_step: 0.0, ..Default::default() });

        let shard_cfg = ShardedServerConfig {
            replicas: 2,
            router: RouterPolicy::RoundRobin,
            router_seed: 0,
            cache: cache.clone(),
            ..Default::default()
        };
        let mut reference = ShardedServer::start(Arc::clone(&model), &shard_cfg);
        let cold_ref = reference.classify(&ds.test.x).expect("aligned batch");
        let warm_ref = reference.classify(&ds.test.x).expect("aligned batch");
        let ref_snap = reference.snapshot();
        reference.shutdown();

        let fleet_cfg = FleetConfig {
            total_replicas: 2,
            router: RouterPolicy::RoundRobin,
            router_seed: 0,
            cache,
            budget: EnergyBudget::unlimited(),
            ..Default::default()
        };
        let mut fleet = Fleet::start(vec![(name.to_string(), model)], &fleet_cfg)
            .expect("fleet start");
        let reqs = FleetRequest::batch(0, &ds.test.x, ds.n_features()).expect("aligned");
        let cold = fleet.classify(&reqs).expect("classify");
        let warm = fleet.classify(&reqs).expect("classify");

        for (refs, flts) in [(&cold_ref, &cold), (&warm_ref, &warm)] {
            assert_eq!(refs.len(), flts.len(), "{name}");
            for (r, f) in refs.iter().zip(flts.iter()) {
                assert_eq!(f.outcome, FleetOutcome::Served { model: 0 }, "{name}");
                let resp = f.response.as_ref().expect("served requests carry responses");
                assert_eq!(r.id, f.id, "{name}");
                assert_eq!(r.id, resp.id, "{name}");
                assert_eq!(r.label, resp.label, "{name} id {}", r.id);
                assert_eq!(r.hops, resp.hops, "{name} id {}", r.id);
                assert_eq!(
                    r.prob, resp.prob,
                    "{name} id {}: fleet prob row is not byte-identical",
                    r.id
                );
            }
        }

        let snap = fleet.snapshot();
        assert_eq!(
            deterministic_counters(&snap.total),
            deterministic_counters(&ref_snap),
            "{name}: fleet metric totals drifted from the plain sharded server"
        );
        assert_eq!(snap.total.fleet_served, snap.total.requests, "{name}");
        assert_eq!(snap.total.fleet_downgraded, 0, "{name}");
        assert_eq!(snap.total.fleet_shed, 0, "{name}");
        fleet.shutdown();
    }
}

/// Budget `0.0` is the degenerate Fig-5 point: no classification is
/// affordable, so everything sheds — even under `Downgrade`, because no
/// fallback model is admissible either. Nothing may reach a replica.
#[test]
fn zero_budget_sheds_everything_even_under_downgrade() {
    let ds = small_data();
    let a = fit_fast("rf", &ds, 42);
    let b = fit_fast("svm_lr", &ds, 43);
    let cfg = FleetConfig {
        budget: EnergyBudget { energy_per_class_nj: Some(0.0), ..Default::default() },
        policy: FleetPolicyKind::Downgrade,
        ..Default::default()
    };
    let mut fleet =
        Fleet::start(vec![("rf".to_string(), a), ("svm_lr".to_string(), b)], &cfg)
            .expect("fleet start");
    let mut reqs = FleetRequest::batch(0, &ds.test.x, ds.n_features()).expect("aligned");
    for (i, r) in reqs.iter_mut().enumerate() {
        r.model = i % 2;
    }
    let responses = fleet.classify(&reqs).expect("classify");
    for (req, resp) in reqs.iter().zip(&responses) {
        assert_eq!(resp.outcome, FleetOutcome::Shed { requested: req.model });
        assert!(resp.response.is_none(), "shed requests must not carry answers");
    }
    let snap = fleet.snapshot();
    assert_eq!(snap.total.fleet_shed as usize, reqs.len());
    assert_eq!(snap.total.responses, 0, "nothing may evaluate under a zero budget");
    assert_eq!(snap.total.evals, 0);
    assert!(snap.downgrades.is_empty(), "a shed is not a downgrade");
    for m in &snap.per_model {
        assert_eq!(m.requested, m.served + m.downgraded_away + m.shed);
        assert_eq!(m.requested, m.shed);
    }
    assert!((snap.total.shed_rate() - 1.0).abs() < 1e-12);
    fleet.shutdown();
}

/// (ISSUE 6 satellite) Pin a budget halfway between the two operating
/// points' measured energies and address everything to `fog_max`: the
/// first classify tick serves (gauges are empty), the second downgrades
/// every request onto `fog_opt` — in registration order, with exact
/// outcome counts on both sides of the `(from, to)` edge.
#[test]
fn tight_budget_downgrades_fog_max_onto_fog_opt_exactly() {
    let ds = small_data();
    let (opt, max) = fog_pair(&ds);
    let e_opt = tile_energy_nj(&opt, &ds);
    let e_max = tile_energy_nj(&max, &ds);
    assert!(
        e_max > e_opt * 1.5,
        "test premise: fog_max ({e_max:.3} nJ/class) must be clearly dearer than \
         early-exit fog_opt ({e_opt:.3} nJ/class)"
    );
    let cfg = FleetConfig {
        total_replicas: 2,
        worker: ModelServerConfig { backend: BackendKind::Uarch, ..Default::default() },
        budget: EnergyBudget {
            energy_per_class_nj: Some((e_opt + e_max) / 2.0),
            ..Default::default()
        },
        policy: FleetPolicyKind::Downgrade,
        ..Default::default()
    };
    let mut fleet = Fleet::start(
        vec![("fog_opt".to_string(), opt), ("fog_max".to_string(), max)],
        &cfg,
    )
    .expect("fleet start");
    let n = ds.test.len() as u64;
    let reqs = FleetRequest::batch(1, &ds.test.x, ds.n_features()).expect("aligned");

    // Tick 1: both gauges read 0 — fog_max serves its own traffic.
    let r1 = fleet.classify(&reqs).expect("classify");
    assert!(
        r1.iter().all(|r| r.outcome == FleetOutcome::Served { model: 1 }),
        "empty gauges must admit the requested model"
    );

    // Tick 2: fog_max's rolling gauge now reads ~e_max >= budget while
    // idle fog_opt still reads 0 — every request downgrades 1 → 0.
    let r2 = fleet.classify(&reqs).expect("classify");
    assert!(
        r2.iter().all(|r| r.outcome == FleetOutcome::Downgraded { from: 1, to: 0 }),
        "over-budget fog_max traffic must fall back onto fog_opt"
    );
    assert!(
        r2.iter().all(|r| r.response.is_some()),
        "downgraded requests still get answers"
    );

    let snap = fleet.snapshot();
    assert_eq!(snap.downgrades, vec![((1, 0), n)]);
    let (m_opt, m_max) = (&snap.per_model[0], &snap.per_model[1]);
    assert_eq!(m_max.requested, 2 * n);
    assert_eq!(m_max.served, n);
    assert_eq!(m_max.downgraded_away, n);
    assert_eq!(m_max.shed, 0);
    assert_eq!(m_max.requested, m_max.served + m_max.downgraded_away + m_max.shed);
    assert_eq!(m_opt.requested, 0);
    assert_eq!(m_opt.downgraded_into, n);
    assert_eq!(snap.total.fleet_served, n);
    assert_eq!(snap.total.fleet_downgraded, n);
    assert_eq!(snap.total.fleet_shed, 0);
    fleet.shutdown();
}

/// (ISSUE 6 satellite regression) Per-model snapshot aggregates stay
/// keyed: each entry reports its *own* arena's nJ/class, matching the
/// standalone tile measurement, while only the merged fleet total
/// blends them.
#[test]
fn per_model_energy_stays_keyed_not_blended() {
    let ds = small_data();
    let (opt, max) = fog_pair(&ds);
    let e_opt = tile_energy_nj(&opt, &ds);
    let e_max = tile_energy_nj(&max, &ds);
    let cfg = FleetConfig {
        total_replicas: 2,
        worker: ModelServerConfig { backend: BackendKind::Uarch, ..Default::default() },
        ..Default::default()
    };
    let mut fleet = Fleet::start(
        vec![("fog_opt".to_string(), opt), ("fog_max".to_string(), max)],
        &cfg,
    )
    .expect("fleet start");
    // Address the full test split to *both* models so each entry
    // evaluates exactly the rows the standalone measurement covered.
    let f = ds.n_features();
    let mut reqs = FleetRequest::batch(0, &ds.test.x, f).expect("aligned");
    reqs.extend(FleetRequest::batch(1, &ds.test.x, f).expect("aligned"));
    let responses = fleet.classify(&reqs).expect("classify");
    assert!(responses.iter().all(|r| !r.outcome.is_shed()));

    let snap = fleet.snapshot();
    let fleet_opt = snap.per_model[0].snapshot.energy_per_class_nj();
    let fleet_max = snap.per_model[1].snapshot.energy_per_class_nj();
    assert!(fleet_opt > 0.0 && fleet_max > 0.0, "uarch energy must surface per model");
    assert!(
        fleet_max > fleet_opt,
        "heterogeneous energy blended: fog_max {fleet_max:.3} <= fog_opt {fleet_opt:.3}"
    );
    // Per-batch ring occupancy differs from the one-tile standalone
    // measurement, so compare with a loose relative tolerance — the
    // keying itself is what this pins, not the exact joule count.
    let rel = |a: f64, b: f64| (a - b).abs() / b;
    assert!(
        rel(fleet_opt, e_opt) < 0.25,
        "fog_opt fleet energy {fleet_opt:.3} nJ/class far from standalone {e_opt:.3}"
    );
    assert!(
        rel(fleet_max, e_max) < 0.25,
        "fog_max fleet energy {fleet_max:.3} nJ/class far from standalone {e_max:.3}"
    );
    let blended = snap.total.energy_per_class_nj();
    assert!(
        fleet_opt < blended && blended < fleet_max,
        "merged total ({blended:.3}) should blend strictly between the \
         per-model gauges ({fleet_opt:.3}, {fleet_max:.3})"
    );
    fleet.shutdown();
}

fn loadgen_fleet(ds: &Dataset, budget_nj: f64, policy: FleetPolicyKind) -> Fleet {
    let (opt, max) = fog_pair(ds);
    let cfg = FleetConfig {
        total_replicas: 2,
        worker: ModelServerConfig { backend: BackendKind::Uarch, ..Default::default() },
        budget: EnergyBudget { energy_per_class_nj: Some(budget_nj), ..Default::default() },
        policy,
        ..Default::default()
    };
    Fleet::start(vec![("fog_opt".to_string(), opt), ("fog_max".to_string(), max)], &cfg)
        .expect("fleet start")
}

/// Every outcome counter of a loadgen report, fleet-wide then per
/// model; the deterministic fingerprint a seed replay must reproduce.
fn outcome_counts(r: &LoadgenReport) -> Vec<u64> {
    let mut v = vec![r.offered, r.served, r.downgraded, r.shed, r.ticks];
    for m in &r.per_model {
        v.extend([m.requested, m.served, m.downgraded_away, m.downgraded_into, m.shed]);
    }
    v
}

/// (ISSUE 6 acceptance) Replaying the seeded open-loop schedule against
/// a freshly-built identical fleet reproduces the outcome counters
/// bit-identically, with nonzero shed under `Strict` and nonzero
/// downgrades under `Downgrade` at the same midpoint budget.
#[test]
fn loadgen_outcomes_replay_bit_identically_from_the_seed() {
    let ds = small_data();
    let (opt, max) = fog_pair(&ds);
    let e_opt = tile_energy_nj(&opt, &ds);
    let e_max = tile_energy_nj(&max, &ds);
    assert!(
        e_max > e_opt * 1.5,
        "test premise: operating points must be clearly separated \
         ({e_opt:.3} vs {e_max:.3} nJ/class)"
    );
    let budget = (e_opt + e_max) / 2.0;
    let lg = LoadgenConfig {
        qps_start: 400.0,
        qps_end: 900.0,
        duration_s: 0.6,
        seed: 7,
        tick_us: 20_000,
        pace: false,
    };

    // Strict: fog_max traffic sheds once its gauge trips.
    let mut a = loadgen_fleet(&ds, budget, FleetPolicyKind::Strict);
    let mut b = loadgen_fleet(&ds, budget, FleetPolicyKind::Strict);
    let ra = loadgen::run(&mut a, &ds.test.x, &lg).expect("loadgen run");
    let rb = loadgen::run(&mut b, &ds.test.x, &lg).expect("loadgen run");
    assert_eq!(
        outcome_counts(&ra),
        outcome_counts(&rb),
        "same seed against an identical fleet must replay the same outcomes"
    );
    assert!(ra.offered > 0);
    assert_eq!(ra.offered, ra.served + ra.downgraded + ra.shed);
    assert!(ra.served > 0);
    assert!(ra.shed > 0, "a midpoint budget must shed fog_max traffic under strict");
    assert_eq!(ra.downgraded, 0, "strict never re-routes");
    assert!(ra.per_model[1].shed > 0);
    assert!(
        ra.per_model[0].energy_per_class_nj > 0.0,
        "uarch energy must surface in the per-model report"
    );
    assert!(
        ra.per_model[1].energy_per_class_nj > ra.per_model[0].energy_per_class_nj,
        "per-model loadgen energy must stay keyed even under partial service"
    );
    a.shutdown();
    b.shutdown();

    // Downgrade: the same over-budget traffic falls back onto fog_opt.
    let mut c = loadgen_fleet(&ds, budget, FleetPolicyKind::Downgrade);
    let mut d = loadgen_fleet(&ds, budget, FleetPolicyKind::Downgrade);
    let rc = loadgen::run(&mut c, &ds.test.x, &lg).expect("loadgen run");
    let rd = loadgen::run(&mut d, &ds.test.x, &lg).expect("loadgen run");
    assert_eq!(outcome_counts(&rc), outcome_counts(&rd));
    assert!(rc.downgraded > 0, "a midpoint budget must downgrade fog_max traffic");
    assert_eq!(rc.shed, 0, "fog_opt stays within budget, so nothing sheds");
    assert_eq!(rc.offered, ra.offered, "the schedule is policy-independent");
    assert_eq!(rc.per_model[0].downgraded_into, rc.downgraded);
    c.shutdown();
    d.shutdown();
}

/// Malformed requests fail with friendly errors and leave the fleet
/// serviceable.
#[test]
fn bad_requests_fail_with_friendly_errors() {
    let ds = small_data();
    let model = fit_fast("svm_lr", &ds, 44);
    let mut fleet = Fleet::start(
        vec![("svm_lr".to_string(), model)],
        &FleetConfig::default(),
    )
    .expect("fleet start");
    let f = ds.n_features();

    let err = fleet
        .classify(&[FleetRequest { model: 3, features: ds.test.x[..f].to_vec() }])
        .expect_err("out-of-range model index must not serve");
    assert!(err.to_string().contains("model index"), "unhelpful error: {err}");

    let err = fleet
        .classify(&[FleetRequest { model: 0, features: vec![0.0; f + 1] }])
        .expect_err("wrong-width row must not serve");
    assert!(err.to_string().contains("features"), "unhelpful error: {err}");

    let err = FleetRequest::batch(0, &ds.test.x[..f + 1], f)
        .expect_err("ragged buffer must not expand");
    assert!(err.to_string().contains("ragged"), "unhelpful error: {err}");

    // Rejected batches must not wedge the fleet: a good one still serves.
    let ok = fleet
        .classify(&FleetRequest::batch(0, &ds.test.x[..f], f).expect("aligned"))
        .expect("classify");
    assert_eq!(ok[0].outcome, FleetOutcome::Served { model: 0 });
    fleet.shutdown();
}
