//! Randomized property tests over the crate's invariants (a proptest
//! substitute: the offline vendor set has no proptest, so cases are
//! drawn from the crate's own deterministic PRNG — failures reproduce
//! exactly from the printed case seed).

use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Split;
use fog::dt::builder::{fit_tree, TreeParams};
use fog::dt::FlatTree;
use fog::fog::confidence::max_diff;
use fog::fog::{FieldOfGroves, FogParams};
use fog::forest::{ForestParams, RandomForest};
use fog::uarch::queue::{DataQueue, Entry};
use fog::util::rng::Rng;
use fog::util::two_max;

const CASES: usize = 60;

/// Random dataset with random dimensionality.
fn random_split(rng: &mut Rng) -> Split {
    let f = 2 + rng.gen_range(10);
    let c = 2 + rng.gen_range(4);
    let n = 40 + rng.gen_range(160);
    let mut s = Split::new(f, c);
    // Random per-class means so trees have something to find.
    let means: Vec<Vec<f32>> = (0..c)
        .map(|_| (0..f).map(|_| rng.gen_normal() * 2.0).collect())
        .collect();
    let mut row = vec![0.0f32; f];
    for i in 0..n {
        let y = i % c;
        for (j, r) in row.iter_mut().enumerate() {
            *r = means[y][j] + rng.gen_normal();
        }
        s.push(&row, y);
    }
    s
}

#[test]
fn prop_two_max_matches_sort() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..CASES {
        let len = 1 + rng.gen_range(12);
        let xs: Vec<f32> = (0..len).map(|_| rng.gen_f32()).collect();
        let (m1, m2) = two_max(&xs);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(m1, sorted[0], "case {case}");
        let want2 = if len > 1 { sorted[1] } else { sorted[0] };
        assert_eq!(m2, want2, "case {case}: {xs:?}");
        assert!((max_diff(&xs) - (m1 - m2).abs()) < 1e-6);
    }
}

#[test]
fn prop_tree_valid_and_flat_equivalent() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let s = random_split(&mut rng);
        let params = TreeParams {
            max_depth: 1 + rng.gen_range(7),
            min_samples_leaf: 1 + rng.gen_range(3),
            ..Default::default()
        };
        let idx: Vec<usize> = (0..s.len()).collect();
        let tree = fit_tree(&s, &idx, &params, &mut rng);
        tree.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert!(tree.depth <= params.max_depth);

        let flat = FlatTree::from_tree(&tree, tree.depth.max(1));
        for i in 0..s.len() {
            let a = tree.predict_proba(s.row(i));
            let b = flat.predict_proba(s.row(i));
            for (p, q) in a.iter().zip(b) {
                assert!((p - q).abs() < 1e-6, "case {case} row {i}");
            }
        }
    }
}

#[test]
fn prop_grove_split_is_partition() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..CASES {
        let s = random_split(&mut rng);
        let n_trees = 2 + rng.gen_range(15);
        let params = ForestParams {
            n_trees,
            tree: TreeParams { max_depth: 5, ..Default::default() },
            bootstrap: true,
        };
        let rf = RandomForest::fit(&s, &params, rng.next_u64());
        let k = 1 + rng.gen_range(n_trees);
        let fog = FieldOfGroves::from_forest_shuffled(&rf, k, Some(rng.next_u64()));
        fog.validate_partition(n_trees)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(fog.n_groves(), n_trees.div_ceil(k), "case {case}");
    }
}

#[test]
fn prop_hops_bounded_and_probs_normalized() {
    let mut rng = Rng::new(0xD00D);
    for case in 0..CASES / 2 {
        let ds = generate(&DatasetProfile::demo(), rng.next_u64());
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), rng.next_u64());
        let k = 1 + rng.gen_range(rf.n_trees());
        let fog = FieldOfGroves::from_forest(&rf, k);
        let max_hops = 1 + rng.gen_range(fog.n_groves());
        let threshold = rng.gen_f32() * 1.2;
        let params = FogParams { threshold, max_hops, seed: rng.next_u64() };
        let res = fog.evaluate(&ds.test.x, &params);
        for o in &res.outcomes {
            assert!(o.hops >= 1 && o.hops <= max_hops, "case {case}: hops {}", o.hops);
            let sum: f32 = o.prob.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "case {case}: prob sum {sum}");
            // Stopped early ⇒ confident, or the hop budget ran out.
            if o.hops < max_hops {
                assert!(
                    o.confidence >= threshold,
                    "case {case}: stopped at {} hops with conf {} < thr {threshold}",
                    o.hops,
                    o.confidence
                );
            }
        }
    }
}

#[test]
fn prop_threshold_monotone_hops() {
    let mut rng = Rng::new(0xF00);
    for case in 0..8 {
        let ds = generate(&DatasetProfile::demo(), rng.next_u64());
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), rng.next_u64());
        let fog = FieldOfGroves::from_forest(&rf, 2);
        let seed = rng.next_u64();
        let mut last = 0.0f64;
        for thr in [0.0f32, 0.25, 0.5, 0.75, 1.0, 1.2] {
            let res = fog.evaluate(
                &ds.test.x,
                &FogParams { threshold: thr, max_hops: fog.n_groves(), seed },
            );
            let h = res.avg_hops();
            assert!(h + 1e-12 >= last, "case {case}: thr {thr} hops {h} < {last}");
            last = h;
        }
    }
}

#[test]
fn prop_queue_never_overflows_and_preserves_entries() {
    let mut rng = Rng::new(0x9A9A);
    for case in 0..CASES {
        let f = 1 + rng.gen_range(20);
        let c = 2 + rng.gen_range(8);
        let gamma = 1 + f + 1 + c;
        let cap_entries = 1 + rng.gen_range(6);
        let mut q = DataQueue::new(f, c, gamma * cap_entries);
        assert_eq!(q.capacity_entries(), cap_entries, "case {case}");
        let mut model: std::collections::VecDeque<u32> = Default::default();
        for op in 0..200 {
            match rng.gen_range(3) {
                0 => {
                    let e = Entry { id: op, hops: 0, features: vec![0.0; f], prob: vec![0.0; c] };
                    if q.push_back(e).is_ok() {
                        model.push_back(op);
                    } else {
                        assert_eq!(model.len(), cap_entries, "case {case}: spurious full");
                    }
                }
                1 => {
                    let e = Entry { id: op, hops: 1, features: vec![0.0; f], prob: vec![0.0; c] };
                    if q.push_front(e).is_ok() {
                        model.push_front(op);
                    } else {
                        assert_eq!(model.len(), cap_entries);
                    }
                }
                _ => {
                    let got = q.pop_front().map(|e| e.id);
                    assert_eq!(got, model.pop_front(), "case {case} op {op}");
                }
            }
            q.check_invariants().unwrap();
        }
    }
}

#[test]
fn prop_repad_any_depth_preserves_function() {
    let mut rng = Rng::new(0x7AD);
    for case in 0..CASES / 2 {
        let s = random_split(&mut rng);
        let idx: Vec<usize> = (0..s.len()).collect();
        let params = TreeParams { max_depth: 1 + rng.gen_range(5), ..Default::default() };
        let tree = fit_tree(&s, &idx, &params, &mut rng);
        let flat = FlatTree::from_tree(&tree, tree.depth.max(1));
        let extra = rng.gen_range(4);
        let padded = flat.repad(flat.depth + extra);
        for i in 0..s.len().min(40) {
            assert_eq!(
                flat.predict(s.row(i)),
                padded.predict(s.row(i)),
                "case {case} row {i}"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    use fog::util::json::{parse, Json};
    let mut rng = Rng::new(0x15EED);
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_range(2) == 0),
            2 => Json::Num((rng.gen_f64() * 2000.0 - 1000.0 * 0.5).round() / 8.0),
            3 => Json::Str(format!("s{}", rng.gen_range(1000))),
            4 => Json::Arr((0..rng.gen_range(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
    }
}
