//! Conformance tests for the `exec` arena engine: batch-tiled prediction
//! through `ForestArena`/`BatchPlan` must be bit-identical (same argmax,
//! probs within 1e-6 — in practice exact) to independent per-tree
//! `FlatTree` traversal for every tree-based registry model
//! (`rf`, `rf_prob`, `fog_opt`, `fog_max`).

use fog::api::spec::forest_params_for;
use fog::api::{Classifier, Estimator, FogModel, ModelSpec};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::dt::FlatTree;
use fog::energy::model::ClassifierKind;
use fog::fog::confidence::max_diff;
use fog::forest::RandomForest;
use fog::{FieldOfGroves, FogParams};

fn data() -> Dataset {
    generate(&DatasetProfile::demo(), 501)
}

/// Reference per-tree probability average, accumulated in the same order
/// as the kernel (sum in tree order, scale once at the end).
fn flat_prob_average(flats: &[FlatTree], x: &[f32], c: usize) -> Vec<f32> {
    let mut acc = vec![0.0f32; c];
    for t in flats {
        for (a, &p) in acc.iter_mut().zip(t.predict_proba(x)) {
            *a += p;
        }
    }
    let inv = 1.0 / flats.len() as f32;
    acc.iter_mut().for_each(|v| *v *= inv);
    acc
}

/// Reference per-tree majority-vote fractions.
fn flat_vote_fractions(flats: &[FlatTree], x: &[f32], c: usize) -> Vec<f32> {
    let mut votes = vec![0.0f32; c];
    for t in flats {
        votes[t.predict(x)] += 1.0;
    }
    let inv = 1.0 / flats.len() as f32;
    votes.iter_mut().for_each(|v| *v *= inv);
    votes
}

fn assert_rows_match(name: &str, i: usize, got: &[f32], want: &[f32]) {
    assert_eq!(
        fog::util::argmax(got),
        fog::util::argmax(want),
        "{name} row {i}: argmax diverged ({got:?} vs {want:?})"
    );
    for (a, b) in got.iter().zip(want) {
        assert!((a - b).abs() < 1e-6, "{name} row {i}: {a} vs {b}");
    }
}

/// `rf` / `rf_prob` registry models: the arena batch path must equal
/// per-tree traversal of the identically-trained forest, flattened.
#[test]
fn rf_registry_models_match_per_tree_flat_traversal() {
    let ds = data();
    let (f, c) = (ds.n_features(), ds.n_classes());
    let seed = 42;
    // Reference forest: `ModelSpec::fit` for the rf family is exactly
    // `RandomForest::fit(data, forest_params_for(..), seed)`.
    let rf = RandomForest::fit(&ds.train, &forest_params_for(f, c), seed);
    let flats = rf.flatten(rf.max_depth());
    let n = ds.test.len();

    for (name, majority) in [("rf", true), ("rf_prob", false)] {
        let model = ModelSpec::for_shape(name, f, c).unwrap().fit(&ds.train, seed);
        let probs = model.predict_proba_batch(&ds.test.x, n);
        let labels = model.predict_batch(&ds.test.x, n);
        assert_eq!(probs.n_rows(), n);
        for i in 0..n {
            let x = ds.test.row(i);
            let want = if majority {
                flat_vote_fractions(&flats, x, c)
            } else {
                flat_prob_average(&flats, x, c)
            };
            assert_rows_match(name, i, probs.row(i), &want);
            assert_eq!(labels[i], fog::util::argmax(&want), "{name} row {i}");
        }
    }
}

/// Replay Algorithm 2 with materialized per-grove `FlatTree`s and compare
/// against the model's arena-backed batch path.
fn check_fog_model(name: &str, model: &FogModel, ds: &Dataset) {
    let c = ds.n_classes();
    let n = ds.test.len();
    let n_groves = model.fog.n_groves();
    let grove_flats: Vec<Vec<FlatTree>> =
        model.fog.groves.iter().map(|g| g.trees()).collect();
    let probs = model.predict_proba_batch(&ds.test.x, n);
    let labels = model.predict_batch(&ds.test.x, n);

    for i in 0..n {
        let x = ds.test.row(i);
        let start = model.start_grove(x);
        let mut prob = vec![0.0f32; c];
        let mut norm = vec![0.0f32; c];
        for j in 0..model.params.max_hops {
            let g = (start + j) % n_groves;
            let trees = &grove_flats[g];
            let inv = 1.0 / trees.len() as f32;
            for t in trees {
                for (a, &p) in prob.iter_mut().zip(t.predict_proba(x)) {
                    *a += p * inv;
                }
            }
            let hinv = 1.0 / (j + 1) as f32;
            for (nm, &p) in norm.iter_mut().zip(&prob) {
                *nm = p * hinv;
            }
            if max_diff(&norm) >= model.params.threshold {
                break;
            }
        }
        assert_rows_match(name, i, probs.row(i), &norm);
        assert_eq!(labels[i], fog::util::argmax(&norm), "{name} row {i}");
    }
}

/// `fog_opt`-style operating point (confidence-gated hops) and `fog_max`
/// (full circulation): arena hop traversal equals per-tree traversal.
#[test]
fn fog_models_match_per_tree_flat_traversal() {
    let ds = data();
    let (f, c) = (ds.n_features(), ds.n_classes());
    let seed = 7;
    let rf = RandomForest::fit(&ds.train, &forest_params_for(f, c), seed);
    let field = FieldOfGroves::from_forest_shuffled(&rf, 2, Some(seed ^ 0x5EED));
    let n_groves = field.n_groves();

    let opt = FogModel::new(
        field.clone(),
        FogParams { threshold: 0.35, max_hops: n_groves, seed },
        ClassifierKind::FogOpt,
    );
    check_fog_model("fog_opt", &opt, &ds);

    let max = FogModel::fog_max(field, seed);
    check_fog_model("fog_max", &max, &ds);
}

/// A deliberately ragged forest: deep trees, depth-capped trees and a
/// hand-built depth-0 (leaf-only) tree packed into one arena.
fn ragged_flats(ds: &Dataset, seed: u64) -> Vec<FlatTree> {
    let (f, c) = (ds.n_features(), ds.n_classes());
    let deep = RandomForest::fit(&ds.train, &forest_params_for(f, c), seed);
    let shallow_params = fog::forest::ForestParams {
        n_trees: 6,
        tree: fog::dt::TreeParams { max_depth: 2, ..fog::dt::TreeParams::default() },
        bootstrap: true,
    };
    let shallow = RandomForest::fit(&ds.train, &shallow_params, seed ^ 0xA5);
    let mut trees = deep.flatten(deep.max_depth());
    trees.extend(shallow.flatten(shallow.max_depth()));
    // Leaf-only tree: a bare class-0 distribution, no splits at all.
    let mut dist = vec![0.0f32; c];
    dist[0] = 1.0;
    trees.push(FlatTree {
        depth: 0,
        n_features: f,
        n_classes: c,
        feat: vec![],
        thr: vec![],
        leaf: dist,
    });
    trees
}

/// Ragged-forest conformance: on a forest mixing depth-0, depth-capped
/// and deep trees, the live-depth early-exit kernel is **bitwise** equal
/// to independent per-tree `FlatTree` traversal and to the forced
/// padded-depth walk, for both reductions and odd tile sizes.
#[test]
fn ragged_forest_kernel_bitwise_matches_per_tree_traversal() {
    use fog::exec::{BatchPlan, ForestArena, Reduce};
    let ds = data();
    let trees = ragged_flats(&ds, 91);
    let arena = ForestArena::from_flat_trees(&trees);
    assert!(
        arena.skipped_ops_per_eval_range(0, arena.n_trees()) > 0,
        "fixture must actually be ragged"
    );
    assert_eq!(arena.live_depth(trees.len() - 1), 0, "leaf-only tree must have live depth 0");
    // Reference per-tree traversal replays the *padded* trees.
    let padded: Vec<FlatTree> = trees.iter().map(|t| t.repad(arena.depth())).collect();
    let n = ds.test.len();
    let c = ds.n_classes();

    let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
    let walk = BatchPlan::new(&arena, Reduce::ProbAverage)
        .with_padded_walk(true)
        .execute(&ds.test.x, n);
    assert_eq!(probs, walk, "early exit changed an answer vs the padded walk");
    let odd = BatchPlan::new(&arena, Reduce::ProbAverage)
        .with_tile(5)
        .execute(&ds.test.x, n);
    assert_eq!(probs, odd, "tile size changed a ragged answer");
    for i in 0..n {
        let x = ds.test.row(i);
        let want = flat_prob_average(&padded, x, c);
        assert_eq!(probs.row(i), &want[..], "ragged row {i} != per-tree traversal");
    }

    let votes = BatchPlan::new(&arena, Reduce::MajorityVote).execute(&ds.test.x, n);
    let votes_walk = BatchPlan::new(&arena, Reduce::MajorityVote)
        .with_padded_walk(true)
        .execute(&ds.test.x, n);
    assert_eq!(votes, votes_walk);
    for i in (0..n).step_by(7) {
        let want = flat_vote_fractions(&padded, ds.test.row(i), c);
        assert_eq!(votes.row(i), &want[..], "ragged vote row {i}");
    }
}

/// Ragged-forest accounting: the early exit must not move a single
/// pre-exit number — comparator-op charge stays trees × padded depth,
/// VMEM/sparse-storage bytes stay the per-tree sums — while the new
/// live/skipped split partitions the charge exactly.
#[test]
fn ragged_forest_accounting_equals_pre_exit_numbers() {
    use fog::exec::ForestArena;
    let ds = data();
    let trees = ragged_flats(&ds, 92);
    let arena = ForestArena::from_flat_trees(&trees);
    let t_cnt = arena.n_trees();
    let depth = arena.depth();

    // Pre-exit comparator charge: every tree × padded depth.
    assert_eq!(arena.ops_per_eval_range(0, t_cnt), t_cnt * depth);
    // The ragged split partitions it without changing it.
    assert_eq!(
        arena.live_ops_per_eval_range(0, t_cnt) + arena.skipped_ops_per_eval_range(0, t_cnt),
        arena.ops_per_eval_range(0, t_cnt)
    );
    // VMEM equals the sum over the homogenized per-tree footprints, and
    // sparse storage equals the live-node bytes of the original trees
    // (padding provisions nothing).
    let per_tree_vmem: usize = trees.iter().map(|t| t.repad(depth).vmem_bytes()).sum();
    assert_eq!(arena.vmem_bytes(), per_tree_vmem);
    let live_sum: usize = trees
        .iter()
        .map(|t| {
            let live = t.thr.iter().filter(|v| v.is_finite() && **v < 1e37).count();
            live * 6 + (live + 1) * t.n_classes
        })
        .sum();
    assert_eq!(arena.sparse_storage_bytes_range(0, t_cnt), live_sum);
}

/// Batched, per-sample and registry-constructed predictions agree for
/// every tree-based registry entry (the arena path is position- and
/// tile-independent).
#[test]
fn tree_registry_batch_equals_per_sample() {
    let ds = data();
    for name in ["rf", "rf_prob", "fog_opt", "fog_max"] {
        let model = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap()
            .fast()
            .fit(&ds.train, 11);
        let n = ds.test.len();
        let batch = model.predict_proba_batch(&ds.test.x, n);
        for i in (0..n).step_by(7) {
            let single = model.predict_proba(ds.test.row(i));
            assert_eq!(batch.row(i), &single[..], "{name} row {i}");
        }
    }
}
