//! Execution-backend throughput: the software arena kernel vs the
//! hardware-in-the-loop μarch backend over identical sample tiles, for
//! every tree-based registry model.
//! (criterion is unavailable offline; `util::bench` is the harness.)
//!
//! Run: `cargo bench --bench backend` (FOG_BENCH_FAST=1 for the CI smoke
//! run with tiny sample counts).
//!
//! Answers are byte-identical across backends (pinned by
//! `rust/tests/backend.rs`); this bench tracks the *price of the
//! accounting* — how much wall-clock the cycle-level simulation adds per
//! tile — and emits the simulated per-classification cycles, energy and
//! comparator ops as `BENCH_JSON` lines so the hardware-in-the-loop
//! numbers are tracked from PR to PR alongside throughput.

use fog::api::{BackendKind, Classifier, Estimator, ModelSpec};
use fog::util::bench::{black_box, Bencher};

const TREE_MODELS: &[&str] = &["rf", "rf_prob", "fog_opt", "fog_max"];

fn main() {
    let fast = std::env::var("FOG_BENCH_FAST").is_ok();
    let batch = if fast { 32 } else { 256 };
    let mut b = Bencher::default();
    let ds = fog::data::synthetic::generate(&fog::data::synthetic::DatasetProfile::demo(), 42);
    let f = ds.n_features();

    // The demo test split is smaller than the target batch; tile its rows
    // round-robin so the batch stays on-profile.
    let mut x = Vec::with_capacity(batch * f);
    for i in 0..batch {
        x.extend_from_slice(ds.test.row(i % ds.test.len()));
    }

    for &name in TREE_MODELS {
        let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .expect("registry name");
        let spec = if fast { spec.fast() } else { spec };
        let model = spec.fit(&ds.train, 1);
        let sw = model.exec_backend(BackendKind::Software).expect("software backend");
        let ua = model.exec_backend(BackendKind::Uarch).expect("uarch backend");

        b.bench(&format!("{name}/software_tile/n{batch}"), batch, || {
            black_box(sw.evaluate_tile(black_box(&x), batch));
        });
        let sw_m = b.results.last().unwrap().clone();

        b.bench(&format!("{name}/uarch_tile/n{batch}"), batch, || {
            black_box(ua.evaluate_tile(black_box(&x), batch));
        });
        let ua_m = b.results.last().unwrap().clone();

        // One clean tile per backend for the accounting figures (the
        // software report carries the ragged kernel's skipped levels;
        // the μarch PE is depth-bound and reports none).
        let (_, report) = ua.evaluate_tile(&x, batch);
        let (_, sw_report) = sw.evaluate_tile(&x, batch);
        let overhead = ua_m.median_ns / sw_m.median_ns.max(1.0);
        println!(
            "sim {name:<8} batch {batch}: {:.1} cycles/cls, {:.4} nJ/cls, \
             {:.0} comparator ops/cls ({overhead:.2}x software wall-clock)",
            report.cycles_per_class(),
            report.energy_per_class_nj(),
            report.comparator_ops_per_class()
        );
        println!(
            "BENCH_JSON {{\"bench\":\"backend\",\"model\":\"{name}\",\"batch\":{batch},\
             \"software_tile_ns\":{:.0},\"uarch_tile_ns\":{:.0},\"sim_overhead_x\":{overhead:.3},\
             \"cycles_per_class\":{:.2},\"energy_per_class_nj\":{:.6},\
             \"comparator_ops_per_class\":{:.2},\"levels_skipped_per_class\":{:.2},\
             \"software_per_s\":{:.1}}}",
            sw_m.median_ns,
            ua_m.median_ns,
            report.cycles_per_class(),
            report.energy_per_class_nj(),
            report.comparator_ops_per_class(),
            sw_report.levels_skipped_per_class(),
            sw_m.throughput_per_s.unwrap_or(0.0)
        );
    }
}
