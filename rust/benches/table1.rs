//! Table-1 regeneration bench: times the end-to-end experiment (train
//! every classifier + evaluate accuracy/energy) per dataset, then prints
//! the table rows themselves — `cargo bench --bench table1` regenerates
//! the paper's Table 1 on the synthetic profiles.
//!
//! FOG_BENCH_FAST=1 restricts to the demo profile.

use fog::data::synthetic::DatasetProfile;
use fog::experiments::table1;
use fog::util::bench::Bencher;

fn main() {
    let fast = std::env::var("FOG_BENCH_FAST").is_ok();
    let profiles: Vec<DatasetProfile> = if fast {
        vec![DatasetProfile::demo()]
    } else {
        // penbase + segmentation keep the bench under a minute; the full
        // five-dataset run is `cargo run --release -- table1`.
        ["penbase", "segmentation"]
            .iter()
            .map(|n| DatasetProfile::by_name(n).unwrap())
            .collect()
    };

    let mut b = Bencher::default();
    // One timed iteration per dataset (training dominates; min_time keeps
    // the sample count small automatically).
    for p in &profiles {
        let profile = p.clone();
        b.bench(&format!("table1_suite_{}", p.name), 1, || {
            let results = table1::run(std::slice::from_ref(&profile), 42);
            assert_eq!(results[0].rows.len(), 7);
        });
    }

    // And regenerate the actual table for the benched profiles.
    let results = table1::run(&profiles, 42);
    table1::print_table(&results);
    table1::print_headline(&results);
}
