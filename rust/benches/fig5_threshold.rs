//! Figure-5 regeneration bench: threshold sweep (run-time tunability)
//! for the 8×2 and 4×4 topologies. Times the sweep and prints both
//! series.
//!
//! FOG_BENCH_FAST=1 uses the demo profile only.

use fog::data::synthetic::DatasetProfile;
use fog::experiments::fig5;
use fog::experiments::suite::train_suite;
use fog::util::bench::Bencher;

fn main() {
    let fast = std::env::var("FOG_BENCH_FAST").is_ok();
    let name = if fast { "demo" } else { "penbase" };
    let profile = DatasetProfile::by_name(name).unwrap();
    let suite = train_suite(&profile, 42);
    let grid = fog::fog::tuner::default_grid();

    let mut b = Bencher::default();
    for topo in [(8usize, 2usize), (4, 4)] {
        b.bench(
            &format!("fig5_threshold_sweep_{name}_{}x{}", topo.0, topo.1),
            grid.len(),
            || {
                let pts = fig5::run_dataset(&suite, topo, &grid, 42).unwrap();
                assert_eq!(pts.len(), grid.len());
            },
        );
    }

    for topo in [(8usize, 2usize), (4, 4)] {
        let pts = fig5::run_dataset(&suite, topo, &grid, 42).unwrap();
        fig5::print_series(topo, &[(name.to_string(), pts)]);
    }
}
