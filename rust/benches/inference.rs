//! Inference throughput: one-call-per-sample vs the batch-tiled arena
//! kernel, for every tree-based registry model.
//! (criterion is unavailable offline; `util::bench` is the harness.)
//!
//! Run: `cargo bench --bench inference` (FOG_BENCH_FAST=1 for a smoke
//! run with tiny sample counts — what CI does on every PR).
//!
//! Three measurements per forest model, two per FoG model:
//! * `sparse_per_sample` (rf/rf_prob only) — the pre-arena hot path: a
//!   per-sample walk of the sparse `DecisionTree`s with per-call
//!   accumulator allocation, exactly what `RfModel` served before the
//!   `exec` refactor.
//! * `api_single_call`  — one `predict_proba` call per sample through the
//!   unified API (today that is the arena kernel at batch 1).
//! * `batch_tiled`      — one `predict_proba_batch` call for the whole
//!   batch. For rf/rf_prob that is the tiled level-synchronous kernel;
//!   for fog_opt/fog_max it is Algorithm 2's confidence-gated per-sample
//!   arena walk, threaded across rows (gating is inherently per-sample).
//!
//! A fourth section benches a **ragged** (mixed-depth) forest: the
//! live-depth early-exit kernel against the forced padded-depth walk on
//! the same arena (`model="ragged_mix"`, `ragged_speedup_x`) — the
//! paper's fewer-comparator-ops argument as wall-clock.
//!
//! A fifth section benches **quantized lanes** on a wide shallow forest
//! (`model="quant_wide"`, `quant_speedup_x`): the exact u8/u16 rank-code
//! tile path against the f32 tile path on the same arena, conformance-
//! asserted byte-identical before timing. The recorded target is ≥ 2×
//! (`quant_speedup_floor` in `BENCH_PALLAS.json`; CI's fast smoke uses
//! the lenient `quant_speedup_floor_fast`). The same section also times
//! the integer tiles under the host's vector kernel against the forced
//! scalar loop (`simd_speedup_x`, `simd` label; `simd_speedup_floor` /
//! `_fast` gates) — again conformance-asserted byte-identical first —
//! plus the two operand-path splits of that win: the vector index
//! gather against the in-kernel scalar gather stage (`gather_speedup_x`,
//! `gather` label) and the vectorized lossy affine coding pass against
//! the per-value scalar closure (`coding_speedup_x`, `coding` label),
//! each reporting 1.0 when its vector form did not dispatch so the
//! floors only arm where the kernels actually ran.
//!
//! Besides the human-readable `bench ...` lines, each model emits one
//! `BENCH_JSON {...}` line; `tools/bench_record.sh` folds those into the
//! repo-root `BENCH_PALLAS.json` trajectory, which the CI gate diffs
//! against to catch throughput regressions.

use fog::api::spec::forest_params_for;
use fog::api::{Classifier, Estimator, ModelSpec};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::dt::TreeParams;
use fog::exec::{BatchPlan, ForestArena, Reduce};
use fog::forest::{ForestParams, RandomForest};
use fog::util::bench::{black_box, Bencher, Measurement};

/// The tree-based registry entries — the models the arena refactor moves.
const TREE_MODELS: &[&str] = &["rf", "rf_prob", "fog_opt", "fog_max"];

fn main() {
    let fast = std::env::var("FOG_BENCH_FAST").is_ok();
    // Acceptance batch size; the smoke run shrinks it so CI stays quick.
    let batch = if fast { 32 } else { 256 };
    let mut b = Bencher::default();
    let ds = generate(&DatasetProfile::demo(), 42);
    let f = ds.n_features();

    // The demo test split is smaller than the target batch; tile its rows
    // round-robin so the batch stays on-profile.
    let mut x = Vec::with_capacity(batch * f);
    for i in 0..batch {
        x.extend_from_slice(ds.test.row(i % ds.test.len()));
    }

    // Pre-refactor reference path: per-sample sparse-forest walks,
    // trained identically to the registry's rf/rf_prob at seed 1
    // (mirroring `ModelSpec::fast`'s forest shrink in smoke mode so the
    // BENCH_JSON numbers stay comparable).
    let mut sparse_params = forest_params_for(f, ds.n_classes());
    if fast {
        sparse_params.n_trees = sparse_params.n_trees.min(8);
        sparse_params.tree.max_depth = sparse_params.tree.max_depth.min(6);
    }
    let sparse_rf = RandomForest::fit(&ds.train, &sparse_params, 1);
    b.bench(&format!("rf_prob/sparse_per_sample/n{batch}"), batch, || {
        for i in 0..batch {
            black_box(sparse_rf.predict_proba(black_box(&x[i * f..(i + 1) * f])));
        }
    });
    let sparse_ref = b.results.last().unwrap().clone();

    let mut summary: Vec<(&str, Measurement, Measurement)> = Vec::new();
    for &name in TREE_MODELS {
        let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .expect("registry name");
        let spec = if fast { spec.fast() } else { spec };
        let model = spec.fit(&ds.train, 1);

        // One unified-API call per sample (arena kernel at batch 1).
        b.bench(&format!("{name}/api_single_call/n{batch}"), batch, || {
            for i in 0..batch {
                black_box(model.predict_proba(black_box(&x[i * f..(i + 1) * f])));
            }
        });
        let single = b.results.last().unwrap().clone();

        // The arena path: one batch-tiled call for all samples.
        b.bench(&format!("{name}/batch_tiled/n{batch}"), batch, || {
            black_box(model.predict_proba_batch(black_box(&x), batch));
        });
        let tiled = b.results.last().unwrap().clone();
        summary.push((name, single, tiled));
    }

    println!();
    for (name, single, tiled) in &summary {
        let speedup = single.median_ns / tiled.median_ns.max(1.0);
        // The sparse pre-refactor baseline only describes the rf family
        // (0 = not applicable, so the JSON stays valid).
        let sparse_ns = if name.starts_with("rf") { sparse_ref.median_ns } else { 0.0 };
        println!(
            "speedup {name:<8} batch {batch}: {speedup:.2}x vs single-call \
             (single {:.0} ns, batch-tiled {:.0} ns, sparse per-sample ref {:.0} ns)",
            single.median_ns, tiled.median_ns, sparse_ns
        );
        println!(
            "BENCH_JSON {{\"bench\":\"inference\",\"model\":\"{name}\",\"batch\":{batch},\
             \"api_single_call_ns\":{:.0},\"batch_tiled_ns\":{:.0},\"sparse_per_sample_ns\":{:.0},\
             \"speedup_vs_single_call\":{:.3},\"batch_tiled_per_s\":{:.1}}}",
            single.median_ns,
            tiled.median_ns,
            sparse_ns,
            speedup,
            tiled.throughput_per_s.unwrap_or(0.0)
        );
    }

    // --- ragged forest: live-depth early exit vs forced padded walk ----
    // Half the trees deep, half depth-capped: the padded walk burns
    // (trees × padded depth) comparisons per sample, the ragged kernel
    // Σ live_depth — the acceptance target is ≥ 1.3× on the tiled path.
    let deep_params = ForestParams {
        n_trees: if fast { 8 } else { 24 },
        tree: TreeParams { max_depth: 12, min_samples_leaf: 1, ..TreeParams::default() },
        bootstrap: true,
    };
    let shallow_params = ForestParams {
        n_trees: deep_params.n_trees,
        tree: TreeParams { max_depth: 3, ..TreeParams::default() },
        bootstrap: true,
    };
    let deep_rf = RandomForest::fit(&ds.train, &deep_params, 5);
    let shallow_rf = RandomForest::fit(&ds.train, &shallow_params, 6);
    let mut trees = deep_rf.flatten(deep_rf.max_depth());
    trees.extend(shallow_rf.flatten(shallow_rf.max_depth()));
    let arena = ForestArena::from_flat_trees(&trees);
    let t_cnt = arena.n_trees();
    let live_frac = arena.live_ops_per_eval_range(0, t_cnt) as f64
        / arena.ops_per_eval_range(0, t_cnt).max(1) as f64;
    let ragged_plan = BatchPlan::new(&arena, Reduce::ProbAverage);
    let padded_plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_padded_walk(true);
    // Conformance smoke before timing: the exit must not move a byte.
    assert_eq!(
        ragged_plan.execute(&x, batch),
        padded_plan.execute(&x, batch),
        "ragged kernel diverged from the padded walk"
    );
    b.bench(&format!("ragged_mix/padded_walk/n{batch}"), batch, || {
        black_box(padded_plan.execute(black_box(&x), batch));
    });
    let padded = b.results.last().unwrap().clone();
    b.bench(&format!("ragged_mix/batch_tiled/n{batch}"), batch, || {
        black_box(ragged_plan.execute(black_box(&x), batch));
    });
    let ragged = b.results.last().unwrap().clone();
    let ragged_speedup = padded.median_ns / ragged.median_ns.max(1.0);
    println!();
    println!(
        "speedup ragged_mix batch {batch}: {ragged_speedup:.2}x vs padded walk \
         (padded {:.0} ns, ragged {:.0} ns, live-op fraction {live_frac:.2}, \
         depth {} over {t_cnt} trees)",
        padded.median_ns,
        ragged.median_ns,
        arena.depth()
    );
    println!(
        "BENCH_JSON {{\"bench\":\"inference\",\"model\":\"ragged_mix\",\"batch\":{batch},\
         \"padded_walk_ns\":{:.0},\"batch_tiled_ns\":{:.0},\"ragged_speedup_x\":{ragged_speedup:.3},\
         \"live_op_fraction\":{live_frac:.4},\"batch_tiled_per_s\":{:.1}}}",
        padded.median_ns,
        ragged.median_ns,
        ragged.throughput_per_s.unwrap_or(0.0)
    );

    // --- quantized lanes: exact u8/u16 tiles vs f32 tiles --------------
    // The wide-forest config the ≥2× acceptance target names: many
    // shallow trees, so the tile loop is compare-bound and lane width is
    // the bottleneck (the fixed-point datapath argument of
    // arXiv 1703.05853 as wall-clock).
    let wide_params = ForestParams {
        n_trees: if fast { 16 } else { 64 },
        tree: TreeParams { max_depth: 5, min_samples_leaf: 1, ..TreeParams::default() },
        bootstrap: true,
    };
    let wide_rf = RandomForest::fit(&ds.train, &wide_params, 7);
    let wide_arena = ForestArena::from_forest(&wide_rf, wide_rf.max_depth());
    let lane = wide_arena.quant_lane().unwrap_or("f32");
    let f32_plan = BatchPlan::new(&wide_arena, Reduce::ProbAverage);
    let quant_plan =
        BatchPlan::new(&wide_arena, Reduce::ProbAverage).with_quant(fog::exec::QuantMode::Exact);
    let scalar_plan = BatchPlan::new(&wide_arena, Reduce::ProbAverage)
        .with_quant(fog::exec::QuantMode::Exact)
        .with_simd(fog::exec::SimdLevel::Scalar);
    let simd = quant_plan.simd_label();
    // Conformance smoke before timing: exact lanes must not move a byte,
    // under native vector dispatch or the forced scalar loop.
    assert_eq!(
        f32_plan.execute(&x, batch),
        quant_plan.execute(&x, batch),
        "exact quantized tile diverged from the f32 kernel"
    );
    assert_eq!(
        scalar_plan.execute(&x, batch),
        quant_plan.execute(&x, batch),
        "vector dispatch ({simd}) diverged from the forced-scalar lane"
    );
    b.bench(&format!("quant_wide/f32_tiled/n{batch}"), batch, || {
        black_box(f32_plan.execute(black_box(&x), batch));
    });
    let f32_tiled = b.results.last().unwrap().clone();
    b.bench(&format!("quant_wide/quant_tiled_{lane}/n{batch}"), batch, || {
        black_box(quant_plan.execute(black_box(&x), batch));
    });
    let quant_tiled = b.results.last().unwrap().clone();
    b.bench(&format!("quant_wide/quant_scalar_{lane}/n{batch}"), batch, || {
        black_box(scalar_plan.execute(black_box(&x), batch));
    });
    let quant_scalar = b.results.last().unwrap().clone();
    let quant_speedup = f32_tiled.median_ns / quant_tiled.median_ns.max(1.0);
    // The vector kernel against its own scalar reference on identical
    // integer tiles — isolates the SIMD win from the lane-narrowing win.
    // 1.0 by construction when dispatch resolves to scalar (f32 lanes,
    // FOG_FORCE_SCALAR=1, or no vector unit), so the floor gate only
    // arms on hosts with a vector kernel.
    let simd_speedup = if simd == "scalar" {
        1.0
    } else {
        quant_scalar.median_ns / quant_tiled.median_ns.max(1.0)
    };

    // The vector index gather against the in-kernel scalar gather stage
    // on identical vector tiles — isolates the operand-load win from the
    // compare/advance win. 1.0 by construction when no vector gather
    // dispatched (scalar/SSE2 hosts, FOG_FORCE_SCALAR_GATHER=1), so the
    // `gather_speedup_floor` gate only arms where a gather kernel ran.
    let scalar_gather_plan = BatchPlan::new(&wide_arena, Reduce::ProbAverage)
        .with_quant(fog::exec::QuantMode::Exact)
        .with_gather(fog::exec::GatherMode::Scalar);
    let gather = quant_plan.gather_label();
    assert_eq!(
        scalar_gather_plan.execute(&x, batch),
        quant_plan.execute(&x, batch),
        "vector gather ({gather}) diverged from the scalar gather stage"
    );
    b.bench(&format!("quant_wide/scalar_gather_{lane}/n{batch}"), batch, || {
        black_box(scalar_gather_plan.execute(black_box(&x), batch));
    });
    let scalar_gather = b.results.last().unwrap().clone();
    let gather_speedup = if gather == "scalar" {
        1.0
    } else {
        scalar_gather.median_ns / quant_tiled.median_ns.max(1.0)
    };

    // The vectorized lossy affine coding pass against the per-value
    // scalar closure, on a lossy plan of the same arena (exact plans
    // have no affine pass). Same arming rule: 1.0 under scalar dispatch.
    let lossy_plan = BatchPlan::new(&wide_arena, Reduce::ProbAverage)
        .with_quant(fog::exec::QuantMode::Lossy { bits: 8 });
    let scalar_coding_plan = BatchPlan::new(&wide_arena, Reduce::ProbAverage)
        .with_quant(fog::exec::QuantMode::Lossy { bits: 8 })
        .with_scalar_coding(true);
    let coding = lossy_plan.coding_label();
    assert_eq!(
        scalar_coding_plan.execute(&x, batch),
        lossy_plan.execute(&x, batch),
        "vector coding ({coding}) diverged from the scalar coding closure"
    );
    b.bench(&format!("quant_wide/lossy_tiled/n{batch}"), batch, || {
        black_box(lossy_plan.execute(black_box(&x), batch));
    });
    let lossy_tiled = b.results.last().unwrap().clone();
    b.bench(&format!("quant_wide/lossy_scalar_coding/n{batch}"), batch, || {
        black_box(scalar_coding_plan.execute(black_box(&x), batch));
    });
    let lossy_scalar = b.results.last().unwrap().clone();
    let coding_speedup = if coding == "scalar" {
        1.0
    } else {
        lossy_scalar.median_ns / lossy_tiled.median_ns.max(1.0)
    };

    println!();
    println!(
        "speedup quant_wide batch {batch}: {quant_speedup:.2}x vs f32 tiles, \
         {simd_speedup:.2}x {simd} vs forced scalar (f32 {:.0} ns, {lane} {:.0} ns, \
         scalar {lane} {:.0} ns, {} trees depth {})",
        f32_tiled.median_ns,
        quant_tiled.median_ns,
        quant_scalar.median_ns,
        wide_arena.n_trees(),
        wide_arena.depth()
    );
    println!(
        "speedup quant_wide gather/coding: {gather_speedup:.2}x {gather} gather vs scalar \
         stage ({:.0} ns vs {:.0} ns), {coding_speedup:.2}x {coding} lossy coding vs \
         per-value closure ({:.0} ns vs {:.0} ns)",
        quant_tiled.median_ns,
        scalar_gather.median_ns,
        lossy_tiled.median_ns,
        lossy_scalar.median_ns
    );
    println!(
        "BENCH_JSON {{\"bench\":\"inference\",\"model\":\"quant_wide\",\"batch\":{batch},\
         \"lanes\":\"{lane}\",\"simd\":\"{simd}\",\"gather\":\"{gather}\",\"coding\":\"{coding}\",\
         \"f32_tiled_ns\":{:.0},\"quant_tiled_ns\":{:.0},\
         \"quant_scalar_ns\":{:.0},\"scalar_gather_ns\":{:.0},\"lossy_tiled_ns\":{:.0},\
         \"lossy_scalar_coding_ns\":{:.0},\"quant_speedup_x\":{quant_speedup:.3},\
         \"simd_speedup_x\":{simd_speedup:.3},\"gather_speedup_x\":{gather_speedup:.3},\
         \"coding_speedup_x\":{coding_speedup:.3},\"batch_tiled_per_s\":{:.1}}}",
        f32_tiled.median_ns,
        quant_tiled.median_ns,
        quant_scalar.median_ns,
        scalar_gather.median_ns,
        lossy_tiled.median_ns,
        lossy_scalar.median_ns,
        quant_tiled.throughput_per_s.unwrap_or(0.0)
    );

    // --- adaptive confidence early exit on the ragged_mix arena --------
    // Per-sample accumulation stops once the running margin clears the
    // threshold (arXiv 2205.13838); t = 1.0 is conformance-asserted
    // byte-identical (and skip-free) before the t = 0.6 point is timed.
    let adaptive_t = 0.6f32;
    let full_plan = BatchPlan::new(&arena, Reduce::ProbAverage);
    let pinned_plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_adaptive(Some(1.0));
    let adaptive_plan =
        BatchPlan::new(&arena, Reduce::ProbAverage).with_adaptive(Some(adaptive_t));
    let (pinned_probs, pinned_skips) = pinned_plan.execute_counting(&x, batch);
    assert_eq!(
        full_plan.execute(&x, batch),
        pinned_probs,
        "t = 1.0 diverged from full evaluation"
    );
    assert_eq!(pinned_skips, 0, "t = 1.0 must not skip a tree");
    let (_, skipped) = adaptive_plan.execute_counting(&x, batch);
    let skipped_per_class = skipped as f64 / batch as f64;
    b.bench(&format!("adaptive_exit/full_eval/n{batch}"), batch, || {
        black_box(full_plan.execute(black_box(&x), batch));
    });
    let full_eval = b.results.last().unwrap().clone();
    b.bench(&format!("adaptive_exit/t{adaptive_t}/n{batch}"), batch, || {
        black_box(adaptive_plan.execute(black_box(&x), batch));
    });
    let adaptive = b.results.last().unwrap().clone();
    let adaptive_speedup = full_eval.median_ns / adaptive.median_ns.max(1.0);
    println!();
    println!(
        "speedup adaptive_exit batch {batch}: {adaptive_speedup:.2}x vs full evaluation \
         (full {:.0} ns, t={adaptive_t} {:.0} ns, {skipped_per_class:.2} of {t_cnt} trees \
         skipped per classification on the ragged_mix arena)",
        full_eval.median_ns,
        adaptive.median_ns
    );
    println!(
        "BENCH_JSON {{\"bench\":\"inference\",\"model\":\"adaptive_exit\",\"batch\":{batch},\
         \"adaptive_conf\":{adaptive_t:.4},\"full_eval_ns\":{:.0},\"adaptive_ns\":{:.0},\
         \"adaptive_speedup_x\":{adaptive_speedup:.3},\"trees_skipped_per_class\":{skipped_per_class:.2},\
         \"batch_tiled_per_s\":{:.1}}}",
        full_eval.median_ns,
        adaptive.median_ns,
        adaptive.throughput_per_s.unwrap_or(0.0)
    );
}
