//! Hot-path micro-benchmarks: the inner loops the perf pass optimizes.
//! (criterion is unavailable offline; `util::bench` is the harness.)
//!
//! Run: `cargo bench --bench hotpath` (FOG_BENCH_FAST=1 for a smoke run)

use fog::data::synthetic::{generate, DatasetProfile};
use fog::fog::confidence::max_diff;
use fog::fog::{FieldOfGroves, FogParams};
use fog::forest::{ForestParams, RandomForest, VoteMode};
use fog::uarch::{RingConfig, RingSim};
use fog::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::default();
    let ds = generate(&DatasetProfile::by_name("penbase").unwrap(), 42);
    let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
    let fog = FieldOfGroves::from_forest(&rf, 2); // 8x2
    let n = ds.test.len();

    // Single flat-tree traversal (the PE inner loop).
    let tree = fog.groves[0].tree(0);
    let x0 = ds.test.row(0);
    b.bench("flat_tree_traversal", 1, || {
        black_box(tree.predict_proba(black_box(x0)));
    });

    // One grove evaluation (one hop's compute).
    let grove = &fog.groves[0];
    let mut acc = vec![0.0f32; grove.n_classes];
    b.bench("grove_eval_single", 1, || {
        acc.iter_mut().for_each(|a| *a = 0.0);
        grove.accumulate_proba(black_box(x0), &mut acc);
        black_box(&acc);
    });

    // MaxDiff confidence.
    let prob = vec![0.09f32, 0.11, 0.1, 0.12, 0.1, 0.08, 0.1, 0.1, 0.1, 0.1];
    b.bench("maxdiff_confidence", 1, || {
        black_box(max_diff(black_box(&prob)));
    });

    // Full Algorithm-2 batch evaluation (threaded).
    let params = FogParams { threshold: 0.3, max_hops: 8, seed: 1 };
    b.bench("fog_evaluate_batch", n, || {
        black_box(fog.evaluate(black_box(&ds.test.x), &params));
    });

    // Conventional RF for comparison.
    b.bench("rf_majority_batch", n, || {
        black_box(rf.accuracy(&ds.test, VoteMode::Majority));
    });

    // Cycle-level ring simulation (per simulated input).
    b.bench("uarch_ring_sim_batch", n, || {
        let mut sim = RingSim::new(
            &fog,
            RingConfig { threshold: 0.3, seed: 1, ..Default::default() },
        );
        sim.load_batch(&ds.test.x);
        black_box(sim.run().len());
    });
}
