//! Figure-4 regeneration bench: topology sweep (accuracy & EDP vs
//! groves × trees/grove). Times the sweep and prints the series.
//!
//! FOG_BENCH_FAST=1 uses the demo profile only.

use fog::data::synthetic::DatasetProfile;
use fog::experiments::fig4;
use fog::experiments::suite::train_suite;
use fog::util::bench::Bencher;

fn main() {
    let fast = std::env::var("FOG_BENCH_FAST").is_ok();
    let name = if fast { "demo" } else { "penbase" };
    let profile = DatasetProfile::by_name(name).unwrap();
    let suite = train_suite(&profile, 42);

    let mut b = Bencher::default();
    b.bench(&format!("fig4_topology_sweep_{name}"), 5, || {
        let pts = fig4::run_dataset(&suite, 42);
        assert_eq!(pts.len(), 5); // factorizations of 16
    });

    let pts = fig4::run_dataset(&suite, 42);
    fig4::print_series(&[(name.to_string(), pts)]);
}
