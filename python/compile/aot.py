"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and aot_recipe).

Outputs:
  artifacts/<name>.hlo.txt   one per (function, shape) pair
  artifacts/manifest.json    shapes + input order, read by rust `runtime`

Run `python -m compile.aot --out-dir ../artifacts` from python/ (the
Makefile's `artifacts` target). Python runs ONCE at build time; the rust
binary is self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Shape sets: (t, depth, f, c, b) per dataset profile, mirroring
# rust data::synthetic::DatasetProfile::paper_suite() and the FoG
# topologies selected by the experiments (8x2 ⇒ t=2 trees per grove).
# ---------------------------------------------------------------------------

DEFAULT_SHAPES = [
    # name-fragment, trees/grove, depth, features, classes, batch
    ("demo", 4, 6, 8, 3, 32),
    ("penbase", 2, 8, 16, 10, 32),   # 8x2 topology
    ("penbase4", 4, 8, 16, 10, 32),  # 4x4 topology (e2e default)
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def grove_specs(t, depth, f, c, b):
    n_int = (1 << depth) - 1
    n_leaves = 1 << depth
    return dict(
        feat=spec((t, n_int), jnp.int32),
        thr=spec((t, n_int)),
        leaf=spec((t, n_leaves, c)),
        x=spec((b, f)),
        prob_sum=spec((b, c)),
        hops=spec((b,)),
    )


def lower_artifact(fn, arg_specs, name, out_dir, manifest, meta):
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    manifest[name] = dict(file=f"{name}.hlo.txt", **meta)
    print(f"  {name}: {len(text)} chars")


def build_all(out_dir: str, shapes) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for tag, t, depth, f, c, b in shapes:
        g = grove_specs(t, depth, f, c, b)
        shape_meta = dict(t=t, depth=depth, n_features=f, n_classes=c, batch=b)

        # Full Algorithm-2 hop: the serving path's executable.
        lower_artifact(
            model.grove_step,
            [g["feat"], g["thr"], g["leaf"], g["x"], g["prob_sum"], g["hops"]],
            f"grove_step_{tag}",
            out_dir,
            manifest,
            dict(
                kind="grove_step",
                inputs=["feat", "thr", "leaf", "x", "prob_sum", "hops"],
                outputs=["new_sum", "norm", "conf"],
                **shape_meta,
            ),
        )
        # Plain grove probabilities: parity tests + quickstart.
        lower_artifact(
            model.grove_proba,
            [g["feat"], g["thr"], g["leaf"], g["x"]],
            f"grove_proba_{tag}",
            out_dir,
            manifest,
            dict(
                kind="grove_proba",
                inputs=["feat", "thr", "leaf", "x"],
                outputs=["proba"],
                **shape_meta,
            ),
        )
        # Standalone confidence kernel.
        lower_artifact(
            model.confidence,
            [spec((b, c))],
            f"maxdiff_{tag}",
            out_dir,
            manifest,
            dict(kind="maxdiff", inputs=["prob"], outputs=["conf"], **shape_meta),
        )

    # GEMM-shaped smoke artifact (runtime multi-input coverage).
    lower_artifact(
        model.mlp_forward,
        [spec((8, 16)), spec((16,)), spec((16, 3)), spec((3,)), spec((4, 8))],
        "mlp_smoke",
        out_dir,
        manifest,
        dict(
            kind="mlp",
            inputs=["w1", "b1", "w2", "b2", "x"],
            outputs=["logits"],
            t=0, depth=0, n_features=8, n_classes=3, batch=4,
        ),
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


def parse_shape(s: str):
    """`tag:t,d,f,c,b` → tuple."""
    tag, nums = s.split(":")
    t, d, f, c, b = (int(v) for v in nums.split(","))
    return (tag, t, d, f, c, b)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shape",
        action="append",
        default=[],
        help="extra artifact shape as tag:t,d,f,c,b (repeatable)",
    )
    args = ap.parse_args()
    shapes = list(DEFAULT_SHAPES) + [parse_shape(s) for s in args.shape]
    build_all(args.out_dir, shapes)


if __name__ == "__main__":
    main()
