"""L2 — the JAX compute graph the rust coordinator executes via PJRT.

The model is the FoG *grove step*: given a grove's flattened trees and a
batch of inputs (plus the running probability sums and hop counts of
Algorithm 2), produce updated sums, normalized distributions and MaxDiff
confidences in one fused HLO module. The rust L3 ring makes the
routing/stopping decisions; this graph is pure data-parallel compute, so
python never appears on the request path.

Everything lowers through the Pallas kernels in `kernels/` (interpret
mode → plain HLO; see kernels/forest.py for the TPU-adaptation notes).
"""

import jax.numpy as jnp

from .kernels.forest import grove_predict_proba
from .kernels.maxdiff import maxdiff


def grove_step(feat, thr, leaf, x, prob_sum, hops):
    """One Algorithm-2 hop for a batch.

    Args:
      feat: i32[t, 2^d - 1]     grove node features
      thr:  f32[t, 2^d - 1]     grove node thresholds
      leaf: f32[t, 2^d, c]      grove leaf distributions
      x:    f32[b, f]           input batch
      prob_sum: f32[b, c]       running sums (zeros for fresh inputs)
      hops: f32[b]              groves contributed *including* this one
    Returns:
      (new_sum f32[b,c], norm f32[b,c], conf f32[b])
    """
    grove_p = grove_predict_proba(feat, thr, leaf, x)
    new_sum = prob_sum + grove_p
    norm = new_sum / hops[:, None]
    conf = maxdiff(norm)
    return new_sum, norm, conf


def grove_proba(feat, thr, leaf, x):
    """Single-grove probabilities (the quickstart/parity artifact)."""
    return (grove_predict_proba(feat, thr, leaf, x),)


def confidence(prob):
    """Standalone MaxDiff artifact."""
    return (maxdiff(prob),)


def mlp_forward(w1, b1, w2, b2, x):
    """Reference 1-hidden-layer MLP forward (AOT-lowering smoke test for
    a GEMM-shaped graph; the paper's MLP baseline runs natively in rust,
    this artifact exists to prove the runtime handles multi-input GEMM
    HLO)."""
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return (h @ w2 + b2,)
