"""Pallas kernel: batched grove traversal (the FoG PE hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's ASIC
walks one node per tree per 3 cycles with a comparator; the TPU analog is
**level-synchronous arithmetic indexing** — one vectorized gather+compare
per level across the whole batch tile, with the grove's node tables
resident in VMEM (they are KBs). BlockSpec tiles the batch dimension;
tree tables are broadcast to every tile (index_map returns block 0).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md); the
interpret path lowers to plain HLO, which is what `aot.py` ships to the
rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: multiple of 8 keeps the VPU lanes full on real hardware and
# divides every batch aot.py emits.
DEFAULT_TILE_B = 32


def _grove_kernel(feat_ref, thr_ref, leaf_ref, x_ref, o_ref, *, depth: int):
    """One batch tile: traverse every tree level-synchronously.

    Refs (VMEM blocks):
      feat_ref: i32[t, n_int]      thr_ref: f32[t, n_int]
      leaf_ref: f32[t, n_leaves, c]
      x_ref:    f32[tile_b, f]     o_ref:   f32[tile_b, c]
    """
    feat = feat_ref[...]
    thr = thr_ref[...]
    leaf = leaf_ref[...]
    x = x_ref[...]
    t, n_int = feat.shape
    tile_b = x.shape[0]
    c = leaf.shape[2]

    def one_tree(tree, acc):
        idx = jnp.zeros((tile_b,), dtype=jnp.int32)
        # Unrolled level loop: `depth` is static, so this lowers to a
        # fixed chain of gathers/compares — one VPU step per level, the
        # level-synchronous schedule described in DESIGN.md.
        for _level in range(depth):
            f_idx = feat[tree, idx]                      # gather [tile_b]
            xv = jnp.take_along_axis(x, f_idx[:, None], axis=1)[:, 0]
            node_thr = thr[tree, idx]
            idx = 2 * idx + 1 + (xv > node_thr).astype(jnp.int32)
        leaf_idx = idx - n_int
        return acc + leaf[tree, leaf_idx, :]             # gather [tile_b, c]

    acc = jax.lax.fori_loop(
        0, t, one_tree, jnp.zeros((tile_b, c), dtype=jnp.float32)
    )
    o_ref[...] = acc / t


def grove_predict_proba(feat, thr, leaf, x, *, tile_b: int = DEFAULT_TILE_B):
    """Grove-averaged class probabilities via the Pallas kernel.

    Args:
      feat: i32[t, 2^d - 1],  thr: f32[t, 2^d - 1]
      leaf: f32[t, 2^d, c],   x: f32[b, f]  (b divisible by tile_b)
    Returns:
      f32[b, c]
    """
    t, n_int = feat.shape
    depth = (n_int + 1).bit_length() - 1
    assert (1 << depth) - 1 == n_int, f"n_int {n_int} not 2^d-1"
    b, f = x.shape
    c = leaf.shape[2]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} not divisible by tile {tile_b}"

    kernel = functools.partial(_grove_kernel, depth=depth)
    grid = (b // tile_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Tree tables: broadcast to every tile (block index fixed at 0).
            pl.BlockSpec((t, n_int), lambda i: (0, 0)),
            pl.BlockSpec((t, n_int), lambda i: (0, 0)),
            pl.BlockSpec((t, 1 << depth, c), lambda i: (0, 0, 0)),
            # Batch: tiled along the grid.
            pl.BlockSpec((tile_b, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(feat, thr, leaf, x)


def vmem_bytes(t: int, depth: int, c: int, f: int, tile_b: int = DEFAULT_TILE_B) -> int:
    """VMEM footprint of one kernel invocation (perf accounting):
    node tables + leaf tables + one batch tile in/out."""
    n_int = (1 << depth) - 1
    n_leaves = 1 << depth
    return (
        t * n_int * 4        # feat (i32)
        + t * n_int * 4      # thr (f32)
        + t * n_leaves * c * 4  # leaf
        + tile_b * f * 4     # x tile
        + tile_b * c * 4     # out tile
    )
