"""Pallas kernel: MaxDiff confidence (Algorithm 2, lines 16-19).

Top-2 difference per probability row without a sort: find the max, mask
exactly that lane (the paper's TwoMaximumValues returns equal values for
duplicated maxima, and masking a single lane reproduces that), take the
max again. Two VPU reductions per row — the same two-comparator cascade
the ASIC uses.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxdiff_kernel(prob_ref, o_ref):
    prob = prob_ref[...]                    # [tile_b, c]
    tile_b, c = prob.shape
    m1 = jnp.max(prob, axis=1)              # [tile_b]
    arg = jnp.argmax(prob, axis=1)          # first maximal lane
    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_b, c), 1)
    masked = jnp.where(lane == arg[:, None], -jnp.inf, prob)
    m2 = jnp.max(masked, axis=1)
    o_ref[...] = jnp.abs(m1 - m2)


def maxdiff(prob, *, tile_b: int = 32):
    """Confidence per row of ``prob: f32[b, c]`` → ``f32[b]``."""
    b, c = prob.shape
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} not divisible by tile {tile_b}"
    return pl.pallas_call(
        _maxdiff_kernel,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(prob)
