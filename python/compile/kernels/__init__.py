"""Pallas kernels (L1) for the FoG accelerator compile path."""
