"""Pure-jnp reference oracle for the Pallas kernels.

Everything here is straight-line jax.numpy with no pallas involvement —
the ground truth the kernels are validated against (pytest + hypothesis),
and the shape/semantics documentation for the rust side.

Forest encoding (shared with rust `dt::flat::FlatTree`):
  feat : i32[t, 2^d - 1]   split feature per internal node (level order)
  thr  : f32[t, 2^d - 1]   split threshold; +inf on dead nodes => route left
  leaf : f32[t, 2^d, c]    per-leaf class distribution
Traversal: ``next = 2*i + 1 + (x[feat[i]] > thr[i])`` for d levels;
leaf index is ``i - (2^d - 1)``. The grove output is the average of its
trees' leaf distributions (Algorithm 2 accumulates one probability-mass
unit per grove).
"""

import jax.numpy as jnp


def grove_predict_proba_ref(feat, thr, leaf, x):
    """Grove-averaged class probabilities.

    Args:
      feat: i32[t, n_int]
      thr:  f32[t, n_int]
      leaf: f32[t, n_leaves, c]
      x:    f32[b, f]
    Returns:
      f32[b, c]
    """
    t, n_int = feat.shape
    depth = (n_int + 1).bit_length() - 1
    b = x.shape[0]
    acc = jnp.zeros((b, leaf.shape[2]), dtype=jnp.float32)
    for tree in range(t):
        idx = jnp.zeros((b,), dtype=jnp.int32)
        for _level in range(depth):
            f_idx = feat[tree, idx]                      # [b]
            xv = jnp.take_along_axis(x, f_idx[:, None], axis=1)[:, 0]
            go_right = (xv > thr[tree, idx]).astype(jnp.int32)
            idx = 2 * idx + 1 + go_right
        leaf_idx = idx - n_int
        acc = acc + leaf[tree, leaf_idx, :]
    return acc / t


def maxdiff_ref(prob):
    """Confidence = difference of the two largest values per row.

    Args:
      prob: f32[b, c]
    Returns:
      f32[b]
    """
    top2 = jnp.sort(prob, axis=1)[:, -2:]
    return jnp.abs(top2[:, 1] - top2[:, 0])


def fog_step_ref(feat, thr, leaf, x, prob_sum, hops):
    """One Algorithm-2 hop: add this grove's estimate, return the new sum,
    the normalized distribution and its confidence.

    Args:
      prob_sum: f32[b, c] running sum (one mass unit per grove so far)
      hops:     number of groves contributed *after* this one (>= 1)
    Returns:
      (new_sum f32[b,c], norm f32[b,c], conf f32[b])
    """
    new_sum = prob_sum + grove_predict_proba_ref(feat, thr, leaf, x)
    norm = new_sum / hops
    return new_sum, norm, maxdiff_ref(norm)
