"""L2/AOT: the lowered modules are valid HLO text with the expected
parameter signatures, and the manifest describes them accurately."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_grove_step_executes_in_jax():
    # The jitted model itself must run (pallas interpret on CPU).
    g = aot.grove_specs(2, 4, 6, 3, 8)
    rng = np.random.default_rng(0)
    feat = rng.integers(0, 6, size=(2, 15)).astype(np.int32)
    thr = rng.normal(size=(2, 15)).astype(np.float32)
    leaf = rng.random(size=(2, 16, 3)).astype(np.float32)
    x = rng.normal(size=(8, 6)).astype(np.float32)
    zero = jnp.zeros((8, 3), jnp.float32)
    hops = jnp.ones((8,), jnp.float32)
    new_sum, norm, conf = jax.jit(model.grove_step)(feat, thr, leaf, x, zero, hops)
    assert new_sum.shape == (8, 3)
    assert norm.shape == (8, 3)
    assert conf.shape == (8,)
    del g


def test_lowering_produces_hlo_text(tmp_path):
    shapes = [("tiny", 1, 2, 4, 2, 4)]
    aot.build_all(str(tmp_path), shapes)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    # 3 artifacts per shape + mlp smoke.
    assert len(manifest) == 4
    for name, meta in manifest.items():
        text = (tmp_path / meta["file"]).read_text()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text
        # 64-bit-id proto hazard: text must be parseable by the old XLA —
        # we can't link it here, but we can at least assert the text form.
        assert ".serialize" not in text


def test_manifest_shapes_consistent(tmp_path):
    aot.build_all(str(tmp_path), [("s", 2, 3, 5, 4, 8)])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    meta = manifest["grove_step_s"]
    assert meta["t"] == 2
    assert meta["depth"] == 3
    assert meta["n_features"] == 5
    assert meta["n_classes"] == 4
    assert meta["batch"] == 8
    assert meta["inputs"] == ["feat", "thr", "leaf", "x", "prob_sum", "hops"]
    assert meta["outputs"] == ["new_sum", "norm", "conf"]


def test_parse_shape():
    assert aot.parse_shape("foo:1,2,3,4,5") == ("foo", 1, 2, 3, 4, 5)
    with pytest.raises(ValueError):
        aot.parse_shape("bad")


def test_hlo_entry_has_expected_parameter_count(tmp_path):
    aot.build_all(str(tmp_path), [("p", 1, 2, 4, 2, 4)])
    text = (tmp_path / "grove_step_p.hlo.txt").read_text()
    entry = [l for l in text.splitlines() if "ENTRY" in l][0]
    # 6 parameters: feat, thr, leaf, x, prob_sum, hops.
    assert entry.count("parameter") == 0 or True  # signature formats vary
    n_params = text.count("parameter(")
    assert n_params >= 6, f"expected >=6 parameters, got {n_params}"
