"""L1 kernel correctness: Pallas vs the pure-jnp oracle, swept over random
shapes and inputs with hypothesis, plus a hand-built numpy cross-check
that is independent of jax entirely."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.forest import grove_predict_proba, vmem_bytes
from compile.kernels.maxdiff import maxdiff
from compile.kernels.ref import (
    fog_step_ref,
    grove_predict_proba_ref,
    maxdiff_ref,
)


def random_grove(rng, t, depth, f, c):
    """Random flattened trees in the shared encoding (some dead nodes)."""
    n_int = (1 << depth) - 1
    n_leaves = 1 << depth
    feat = rng.integers(0, f, size=(t, n_int)).astype(np.int32)
    thr = rng.normal(size=(t, n_int)).astype(np.float32)
    # Sprinkle dead nodes: +inf threshold routes left, as rust pads.
    dead = rng.random(size=(t, n_int)) < 0.2
    thr[dead] = np.float32(1e38)
    leaf = rng.random(size=(t, n_leaves, c)).astype(np.float32)
    leaf /= leaf.sum(axis=2, keepdims=True)
    return feat, thr, leaf


def numpy_traverse(feat, thr, leaf, x):
    """jax-free oracle: per-sample pointer chase, the rust semantics."""
    t, n_int = feat.shape
    depth = (n_int + 1).bit_length() - 1
    b = x.shape[0]
    out = np.zeros((b, leaf.shape[2]), dtype=np.float64)
    for s in range(b):
        for tree in range(t):
            i = 0
            for _ in range(depth):
                go_right = x[s, feat[tree, i]] > thr[tree, i]
                i = 2 * i + 1 + int(go_right)
            out[s] += leaf[tree, i - n_int]
    return (out / t).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 6),
    depth=st.integers(1, 6),
    f=st.integers(2, 24),
    c=st.integers(2, 8),
    b=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_and_numpy(t, depth, f, c, b, seed):
    rng = np.random.default_rng(seed)
    feat, thr, leaf = random_grove(rng, t, depth, f, c)
    x = rng.normal(size=(b, f)).astype(np.float32)

    got = np.asarray(grove_predict_proba(feat, thr, leaf, x, tile_b=min(b, 8)))
    want_ref = np.asarray(grove_predict_proba_ref(feat, thr, leaf, x))
    want_np = numpy_traverse(feat, thr, leaf, x)

    np.testing.assert_allclose(got, want_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got, want_np, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([4, 8, 32]),
    c=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxdiff_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    prob = rng.random(size=(b, c)).astype(np.float32)
    got = np.asarray(maxdiff(prob, tile_b=min(b, 8)))
    want = np.asarray(maxdiff_ref(prob))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_maxdiff_paper_example():
    # §3.2.2 worked example: {0.32,0.35,0.33} → 0.02; {0.3,0.4,0.3} → 0.1.
    prob = np.array(
        [[0.32, 0.35, 0.33], [0.3, 0.4, 0.3]], dtype=np.float32
    )
    got = np.asarray(maxdiff(prob, tile_b=2))
    np.testing.assert_allclose(got, [0.02, 0.1], atol=1e-6)


def test_maxdiff_duplicate_maxima_zero():
    prob = np.array([[0.4, 0.4, 0.2]], dtype=np.float32)
    got = np.asarray(maxdiff(prob, tile_b=1))
    np.testing.assert_allclose(got, [0.0], atol=1e-7)


def test_probabilities_normalized():
    rng = np.random.default_rng(7)
    feat, thr, leaf = random_grove(rng, 4, 5, 10, 6)
    x = rng.normal(size=(16, 10)).astype(np.float32)
    p = np.asarray(grove_predict_proba(feat, thr, leaf, x, tile_b=8))
    np.testing.assert_allclose(p.sum(axis=1), np.ones(16), rtol=1e-5)
    assert (p >= 0).all()


def test_dead_nodes_route_left():
    # A depth-2 tree whose right subtree is dead: feat 0 everywhere,
    # root threshold 0, dead thresholds +inf.
    feat = np.zeros((1, 3), dtype=np.int32)
    thr = np.array([[0.0, 1e38, 1e38]], dtype=np.float32)
    leaf = np.zeros((1, 4, 2), dtype=np.float32)
    leaf[0, 0] = [1, 0]  # left-left
    leaf[0, 2] = [0, 1]  # right-left
    x = np.array([[-1.0], [1.0]], dtype=np.float32)
    p = np.asarray(grove_predict_proba(feat, thr, leaf, x, tile_b=2))
    np.testing.assert_allclose(p, [[1, 0], [0, 1]], atol=1e-7)


def test_fog_step_two_hops_normalization():
    rng = np.random.default_rng(11)
    feat1, thr1, leaf1 = random_grove(rng, 2, 4, 6, 3)
    feat2, thr2, leaf2 = random_grove(rng, 2, 4, 6, 3)
    x = rng.normal(size=(8, 6)).astype(np.float32)
    zero = jnp.zeros((8, 3), dtype=jnp.float32)
    s1, n1, c1 = fog_step_ref(feat1, thr1, leaf1, x, zero, 1.0)
    s2, n2, c2 = fog_step_ref(feat2, thr2, leaf2, x, s1, 2.0)
    # Normalized dist after 2 hops = average of the two grove estimates.
    g1 = grove_predict_proba_ref(feat1, thr1, leaf1, x)
    g2 = grove_predict_proba_ref(feat2, thr2, leaf2, x)
    np.testing.assert_allclose(np.asarray(n2), np.asarray((g1 + g2) / 2), rtol=1e-5)
    assert np.asarray(c2).shape == (8,)


def test_vmem_accounting():
    assert vmem_bytes(2, 8, 10, 16) == (
        2 * 255 * 4 + 2 * 255 * 4 + 2 * 256 * 10 * 4 + 32 * 16 * 4 + 32 * 10 * 4
    )


def test_batch_not_divisible_raises():
    rng = np.random.default_rng(3)
    feat, thr, leaf = random_grove(rng, 1, 2, 4, 2)
    x = rng.normal(size=(6, 4)).astype(np.float32)
    with pytest.raises(AssertionError):
        grove_predict_proba(feat, thr, leaf, x, tile_b=4)
