"""L2 model tests: the fused grove_step graph agrees with composing its
pieces, hop normalization is exact, and the kernels behave across the
shapes aot.py actually emits."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import grove_predict_proba_ref, maxdiff_ref

from tests.test_kernel import random_grove


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 4),
    depth=st.integers(1, 5),
    f=st.integers(2, 16),
    c=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_grove_step_equals_composition(t, depth, f, c, seed):
    rng = np.random.default_rng(seed)
    feat, thr, leaf = random_grove(rng, t, depth, f, c)
    b = 8
    x = rng.normal(size=(b, f)).astype(np.float32)
    prob_sum = rng.random(size=(b, c)).astype(np.float32)
    hops = np.full((b,), 3.0, dtype=np.float32)

    new_sum, norm, conf = jax.jit(model.grove_step)(feat, thr, leaf, x, prob_sum, hops)

    grove_p = grove_predict_proba_ref(feat, thr, leaf, x)
    want_sum = prob_sum + np.asarray(grove_p)
    want_norm = want_sum / 3.0
    np.testing.assert_allclose(np.asarray(new_sum), want_sum, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(norm), want_norm, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(conf), np.asarray(maxdiff_ref(want_norm)), rtol=1e-4, atol=1e-5
    )


def test_grove_step_first_hop_normalization():
    rng = np.random.default_rng(3)
    feat, thr, leaf = random_grove(rng, 2, 3, 5, 4)
    x = rng.normal(size=(8, 5)).astype(np.float32)
    zero = jnp.zeros((8, 4), jnp.float32)
    one = jnp.ones((8,), jnp.float32)
    _, norm, _ = jax.jit(model.grove_step)(feat, thr, leaf, x, zero, one)
    # First hop: normalized == the grove's own distribution, sums to 1.
    np.testing.assert_allclose(np.asarray(norm).sum(axis=1), np.ones(8), rtol=1e-5)


def test_aot_shape_set_runs():
    # Every DEFAULT_SHAPES entry must trace+run under jit (catches shape
    # regressions before the rust side ever sees an artifact).
    from compile import aot

    for tag, t, depth, f, c, b in aot.DEFAULT_SHAPES:
        rng = np.random.default_rng(hash(tag) % 2**31)
        n_int = (1 << depth) - 1
        feat = rng.integers(0, f, size=(t, n_int)).astype(np.int32)
        thr = rng.normal(size=(t, n_int)).astype(np.float32)
        leaf = rng.random(size=(t, 1 << depth, c)).astype(np.float32)
        x = rng.normal(size=(b, f)).astype(np.float32)
        out = jax.jit(model.grove_proba)(feat, thr, leaf, x)[0]
        assert out.shape == (b, c), f"{tag}: {out.shape}"


def test_mlp_forward_shapes():
    rng = np.random.default_rng(5)
    w1 = rng.normal(size=(8, 16)).astype(np.float32)
    b1 = np.zeros(16, np.float32)
    w2 = rng.normal(size=(16, 3)).astype(np.float32)
    b2 = np.zeros(3, np.float32)
    x = rng.normal(size=(4, 8)).astype(np.float32)
    (logits,) = jax.jit(model.mlp_forward)(w1, b1, w2, b2, x)
    assert logits.shape == (4, 3)
    # ReLU hidden: logits must differ from the affine-only path.
    lin = x @ w1 @ w2
    assert not np.allclose(np.asarray(logits), lin)
