#!/usr/bin/env bash
# BENCH_PALLAS.json trajectory tooling: fold the benches' BENCH_JSON
# lines into the repo-root trajectory file, or gate a smoke run against
# the recorded baseline.
#
#   tools/bench_record.sh record [--runs N] [--fast] [--out FILE]
#       Run the inference + backend benches plus the fleet loadgen serve
#       path (`fog serve --fleet fog_opt,fog_max --backend uarch`, whose
#       seeded open-loop schedule makes its serve_fleet/serve_fleet_model
#       BENCH_JSON outcome counts replay-stable) N times (default 3),
#       take the per-metric median for every (bench, model, batch) key,
#       and append one trajectory point to BENCH_PALLAS.json (or --out).
#       --fast sets FOG_BENCH_FAST=1 (CI-sized batches; points are
#       tagged so gate runs only compare like with like). A measured
#       point supersedes any '"estimated": true' placeholder with the
#       same fast tag: the placeholders are dropped from the trajectory
#       when the first real point of their kind lands.
#
#   tools/bench_record.sh gate [--runs N] [--max-regress 0.15] [--out FILE]
#       Smoke-run (FOG_BENCH_FAST=1) the inference bench N times, fold
#       medians, and compare the throughput metrics against the most
#       recent comparable (fast-tagged) point in BENCH_PALLAS.json:
#       fail on a drop larger than --max-regress (default 15%). The
#       3-run median keeps the gate green on noisy runners. Also
#       enforces the armed absolute floors from the trajectory file's
#       "gate" block: the live median ragged_speedup_x must stay above
#       the ragged floor, the live median quant_speedup_x (exact
#       u8/u16 tiles vs f32) above the quant floor, and the live median
#       simd_speedup_x (vector dispatch vs forced-scalar quant tiles)
#       above the simd floor, the live median gather_speedup_x (vector
#       index gather vs the scalar gather stage) above the gather floor,
#       and the live median coding_speedup_x (vectorized lossy affine
#       coding vs the per-value closure) above the coding floor — the
#       simd/gather/coding floors only arm when the host actually
#       dispatched the corresponding vector kernel (label != "scalar"),
#       so scalar-only runners stay green. Passes with a notice
#       when the trajectory has no comparable baseline yet; baseline
#       points tagged "estimated" (seeded off-toolchain) are skipped for
#       the throughput diff.
#
# Requires: a Rust toolchain (cargo) and python3.
set -euo pipefail

cd "$(dirname "$0")/.."
REPO_ROOT=$(pwd)

MODE=${1:-}
shift || true
case "$MODE" in
  record|gate) ;;
  *)
    echo "usage: tools/bench_record.sh <record|gate> [--runs N] [--fast] [--max-regress F] [--out FILE]" >&2
    exit 2
    ;;
esac

RUNS=3
FAST=0
MAX_REGRESS=0.15
OUT="$REPO_ROOT/BENCH_PALLAS.json"
while [ $# -gt 0 ]; do
  case "$1" in
    --runs) RUNS=$2; shift 2 ;;
    --fast) FAST=1; shift ;;
    --max-regress) MAX_REGRESS=$2; shift 2 ;;
    --out) OUT=$2; shift 2 ;;
    *) echo "unknown flag: $1" >&2; exit 2 ;;
  esac
done
[ "$MODE" = gate ] && FAST=1

BENCHES="inference"
[ "$MODE" = record ] && BENCHES="inference backend"

RAW=$(mktemp)
LINES=$(mktemp)
trap 'rm -f "$RAW" "$LINES"' EXIT
# Each cargo bench run must succeed — `set -e` aborts on the first
# failure, so the fold below never sees partial data from a crashed run.
for run in $(seq 1 "$RUNS"); do
  for bench in $BENCHES; do
    echo "[bench_record] run $run/$RUNS: cargo bench --bench $bench (fast=$FAST)" >&2
    if [ "$FAST" = 1 ]; then
      (cd rust && FOG_BENCH_FAST=1 cargo bench --bench "$bench") | tee -a "$RAW"
    else
      (cd rust && cargo bench --bench "$bench") | tee -a "$RAW"
    fi
  done
  if [ "$MODE" = record ]; then
    # The fleet tier's trajectory: an unpaced seeded loadgen ramp against
    # fog_opt + fog_max with live uarch energy. Outcome counters are a
    # pure function of the loadgen seed, so the medians below fold
    # throughput noise only, never admission noise.
    echo "[bench_record] run $run/$RUNS: fog serve --fleet fog_opt,fog_max (loadgen)" >&2
    (cd rust && cargo run --release -- serve --fleet fog_opt,fog_max \
        --backend uarch --dataset demo --loadgen-seed 42) | tee -a "$RAW"
  fi
done
grep '^BENCH_JSON ' "$RAW" | sed 's/^BENCH_JSON //' > "$LINES" || true

if ! [ -s "$LINES" ]; then
  echo "[bench_record] benches ran but emitted no BENCH_JSON lines — output format drifted?" >&2
  exit 1
fi

GIT_REV=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
DATE_UTC=$(date -u +%Y-%m-%dT%H:%M:%SZ)

MODE="$MODE" LINES="$LINES" OUT="$OUT" FAST="$FAST" RUNS="$RUNS" \
MAX_REGRESS="$MAX_REGRESS" GIT_REV="$GIT_REV" DATE_UTC="$DATE_UTC" \
python3 - <<'PY'
import json, os, statistics, sys

mode = os.environ["MODE"]
out_path = os.environ["OUT"]
fast = os.environ["FAST"] == "1"
max_regress = float(os.environ["MAX_REGRESS"])

# Fold: (bench, model, batch) -> metric -> median over runs.
samples = {}
with open(os.environ["LINES"]) as fh:
    for line in fh:
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        key = f'{rec.get("bench","?")}/{rec.get("model","?")}/n{rec.get("batch","?")}'
        bucket = samples.setdefault(key, {})
        for metric, value in rec.items():
            if isinstance(value, (int, float)) and metric not in ("batch",):
                bucket.setdefault(metric, []).append(float(value))
        # Dispatch labels ride along so recorded points say which lane /
        # vector ISA / gather+coding stage produced their numbers
        # (host-comparability).
        for metric in ("lanes", "simd", "gather", "coding"):
            if isinstance(rec.get(metric), str):
                bucket.setdefault(metric, []).append(rec[metric])
folded = {
    key: {
        metric: statistics.median(vals) if isinstance(vals[0], float) else vals[-1]
        for metric, vals in metrics.items()
    }
    for key, metrics in sorted(samples.items())
}

try:
    with open(out_path) as fh:
        trajectory = json.load(fh)
except FileNotFoundError:
    trajectory = {"schema": 1, "points": []}

gate_cfg = trajectory.get("gate", {})
gate_metrics = gate_cfg.get("metrics", ["batch_tiled_per_s", "software_per_s"])
# Fast (CI smoke) runs time microsecond-scale tiles where fixed thread
# dispatch overhead compresses the measurable speedup, so they enforce
# only a lenient "not a pessimization" floor; the full-run floor guards
# the real acceptance target at record time.
if fast:
    speedup_floor = float(gate_cfg.get("ragged_speedup_floor_fast", 0.95))
    quant_floor = float(gate_cfg.get("quant_speedup_floor_fast", 0.8))
    simd_floor = float(gate_cfg.get("simd_speedup_floor_fast", 0.9))
    gather_floor = float(gate_cfg.get("gather_speedup_floor_fast", 0.85))
    coding_floor = float(gate_cfg.get("coding_speedup_floor_fast", 0.9))
else:
    speedup_floor = float(gate_cfg.get("ragged_speedup_floor", 1.1))
    quant_floor = float(gate_cfg.get("quant_speedup_floor", 2.0))
    simd_floor = float(gate_cfg.get("simd_speedup_floor", 1.5))
    gather_floor = float(gate_cfg.get("gather_speedup_floor", 1.1))
    coding_floor = float(gate_cfg.get("coding_speedup_floor", 1.2))

if mode == "record":
    # A measured point makes same-tagged estimated placeholders obsolete:
    # drop them so the gate's "most recent comparable point" scan can
    # never pick a placeholder over real data, and future floors diff
    # against measurements only.
    points = trajectory.setdefault("points", [])
    stale = [
        p for p in points
        if p.get("estimated") and bool(p.get("fast")) == fast
    ]
    if stale:
        trajectory["points"] = points = [p for p in points if p not in stale]
        names = ", ".join(p.get("id", "?") for p in stale)
        print(f"[bench_record] dropping {len(stale)} estimated placeholder "
              f"point(s) superseded by this measured run: {names}")
    points.append(
        {
            "id": f"{os.environ['DATE_UTC']}-{os.environ['GIT_REV']}",
            "date": os.environ["DATE_UTC"],
            "git_rev": os.environ["GIT_REV"],
            "fast": fast,
            "runs": int(os.environ["RUNS"]),
            "entries": folded,
        }
    )
    with open(out_path, "w") as fh:
        json.dump(trajectory, fh, indent=2, sort_keys=False)
        fh.write("\n")
    print(f"[bench_record] appended point {trajectory['points'][-1]['id']} "
          f"({len(folded)} bench keys) to {out_path}")
    sys.exit(0)

# --- gate ---
# Estimated points are placeholders seeded where no toolchain ran the
# benches; they arm the absolute floors but must never serve as a
# throughput baseline.
baseline = None
for point in reversed(trajectory.get("points", [])):
    if bool(point.get("fast")) == fast and not point.get("estimated"):
        baseline = point
        break

failures = []

# Absolute floors: the ragged early-exit win and the quantized-lane win
# must be present in the live run regardless of any baseline.
for key, metrics in folded.items():
    if "ragged_speedup_x" in metrics and metrics["ragged_speedup_x"] < speedup_floor:
        failures.append(
            f"{key}: ragged_speedup_x {metrics['ragged_speedup_x']:.3f} "
            f"< floor {speedup_floor:.2f}"
        )
    if "quant_speedup_x" in metrics and metrics["quant_speedup_x"] < quant_floor:
        failures.append(
            f"{key}: quant_speedup_x {metrics['quant_speedup_x']:.3f} "
            f"< floor {quant_floor:.2f}"
        )
    # The simd floor arms only when a vector kernel actually dispatched
    # (simd_speedup_x is 1.0 by construction under scalar dispatch, so a
    # scalar-only runner — or FOG_FORCE_SCALAR=1 — must stay green).
    if (
        "simd_speedup_x" in metrics
        and metrics.get("simd", "scalar") != "scalar"
        and metrics["simd_speedup_x"] < simd_floor
    ):
        failures.append(
            f"{key}: simd_speedup_x {metrics['simd_speedup_x']:.3f} "
            f"({metrics['simd']}) < floor {simd_floor:.2f}"
        )
    # Same arming rule for the gather and coding floors: each speedup is
    # 1.0 by construction when its vector form did not dispatch
    # (scalar/SSE2 hosts, FOG_FORCE_SCALAR_GATHER=1), so the floors only
    # bite where the kernels actually ran.
    if (
        "gather_speedup_x" in metrics
        and metrics.get("gather", "scalar") != "scalar"
        and metrics["gather_speedup_x"] < gather_floor
    ):
        failures.append(
            f"{key}: gather_speedup_x {metrics['gather_speedup_x']:.3f} "
            f"({metrics['gather']}) < floor {gather_floor:.2f}"
        )
    if (
        "coding_speedup_x" in metrics
        and metrics.get("coding", "scalar") != "scalar"
        and metrics["coding_speedup_x"] < coding_floor
    ):
        failures.append(
            f"{key}: coding_speedup_x {metrics['coding_speedup_x']:.3f} "
            f"({metrics['coding']}) < floor {coding_floor:.2f}"
        )

if baseline is None:
    print("[bench_record] gate: no comparable baseline point in "
          f"{out_path} yet — throughput diff skipped (pass).")
    print("[bench_record] folded medians for this run (commit via "
          "'tools/bench_record.sh record' where a toolchain exists):")
    print(json.dumps(folded, indent=2))
else:
    for key, metrics in folded.items():
        base_metrics = baseline.get("entries", {}).get(key, {})
        for metric in gate_metrics:
            base = base_metrics.get(metric)
            live = metrics.get(metric)
            if not base or live is None:
                continue
            drop = 1.0 - live / base
            status = "FAIL" if drop > max_regress else "ok"
            print(f"[bench_record] {status} {key} {metric}: "
                  f"baseline {base:.1f} live {live:.1f} ({-drop:+.1%})")
            if drop > max_regress:
                failures.append(
                    f"{key} {metric}: {live:.1f} vs baseline {base:.1f} "
                    f"({drop:.1%} drop > {max_regress:.0%})"
                )

if failures:
    print("[bench_record] gate FAILED:", file=sys.stderr)
    for f in failures:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print("[bench_record] gate passed.")
PY
